//! Configuration: a small TOML-subset parser (flat `key = value` pairs,
//! comments, strings/numbers/bools) plus the typed config structs used by
//! the CLI and the serve example. The vendored crate set has no `toml`
//! crate; the subset here covers everything rode's configs need.

use crate::solver::MethodId;
use crate::tensor::Layout;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// A parsed flat config file.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    /// Parse `key = value` lines; `#` starts a comment; quotes optional on
    /// strings.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue; // sections are allowed but flattened/ignored
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            values.insert(k.trim().to_string(), v);
        }
        Ok(Self { values })
    }

    /// Parse the file at `path` (see [`RawConfig::parse`]).
    pub fn load(path: &str) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// The raw string value of `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// `key` parsed as a float; `Err` on a present-but-unparsable value.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| anyhow!("bad float for {key}: {v}")))
            .transpose()
    }

    /// `key` parsed as an unsigned integer; `Err` on a bad value.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| anyhow!("bad integer for {key}: {v}")))
            .transpose()
    }

    /// `key` parsed as `true`/`false`; `Err` on any other value.
    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.get(key)
            .map(|v| match v {
                "true" => Ok(true),
                "false" => Ok(false),
                _ => Err(anyhow!("bad bool for {key}: {v}")),
            })
            .transpose()
    }
}

/// Which worker-pool implementation carries a sharded solve.
///
/// All kinds are **bitwise-identical** in their results (see
/// [`crate::exec`]); they differ only in scheduling:
///
/// - [`PoolKind::Serial`] forces the single-threaded reference path
///   regardless of the thread count — useful to pin down a baseline.
/// - [`PoolKind::Scoped`] fans contiguous near-equal row shards out over
///   freshly spawned scoped threads on every scatter (one shard per
///   worker, static assignment).
/// - [`PoolKind::Persistent`] parks a long-lived worker pool between
///   passes and schedules smaller row chunks through work-stealing
///   deques, so straggler-heavy batches rebalance dynamically instead of
///   serializing on the shard that owns the stiff rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    /// Single-threaded execution on the calling thread.
    Serial,
    /// Scoped threads, spawned per scatter, contiguous static shards.
    Scoped,
    /// Long-lived parked workers with work-stealing chunk queues.
    Persistent,
}

impl PoolKind {
    /// Parse a pool kind as used on the CLI (`--pool`) and in configs
    /// (`pool` key): `serial`, `scoped` or `persistent`.
    pub fn parse(s: &str) -> Option<PoolKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "serial" => PoolKind::Serial,
            "scoped" => PoolKind::Scoped,
            "persistent" => PoolKind::Persistent,
            _ => return None,
        })
    }

    /// The CLI/config spelling of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            PoolKind::Serial => "serial",
            PoolKind::Scoped => "scoped",
            PoolKind::Persistent => "persistent",
        }
    }
}

/// How a solve loop uses CPU workers (the exec layer's knob).
///
/// `threads == 1` is the serial reference path; `threads == 0` requests
/// one worker per available core; any other value pins the worker count.
/// `pool` selects the worker-pool implementation ([`PoolKind`]) and
/// `steal_chunk` the work-stealing chunk granularity in rows (`0` picks
/// a heuristic; ignored by the scoped pool). Sharded execution is
/// bitwise-identical to serial execution for every combination — see
/// [`crate::exec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecPolicy {
    /// Worker count (`0` = one per available core, `1` = serial).
    pub threads: usize,
    /// Worker-pool implementation.
    pub pool: PoolKind,
    /// Rows per work-stealing chunk (`0` = heuristic; persistent only).
    pub steal_chunk: usize,
}

impl Default for ExecPolicy {
    fn default() -> Self {
        Self { threads: 1, pool: PoolKind::Scoped, steal_chunk: 0 }
    }
}

impl ExecPolicy {
    /// The serial reference path (no worker pool).
    pub fn serial() -> Self {
        Self { threads: 1, ..Self::default() }
    }

    /// A fixed worker count on the scoped pool; `0` means one worker per
    /// available core.
    pub fn threads(n: usize) -> Self {
        Self { threads: n, ..Self::default() }
    }

    /// A fixed worker count on the persistent work-stealing pool.
    pub fn persistent(n: usize) -> Self {
        Self { threads: n, pool: PoolKind::Persistent, steal_chunk: 0 }
    }

    /// Resolve `threads == 0` against the machine.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// Resolve the work-stealing chunk size against a batch: an explicit
    /// `steal_chunk` is used as-is; `0` aims for ~4 chunks per worker so
    /// the queues have enough slack to rebalance stragglers. Always at
    /// least 1. The choice never affects results, only scheduling.
    pub fn effective_steal_chunk(&self, batch: usize) -> usize {
        if self.steal_chunk > 0 {
            self.steal_chunk
        } else {
            (batch / (4 * self.effective_threads().max(1))).max(1)
        }
    }
}

/// Top-level service configuration (CLI flags override file values).
#[derive(Debug, Clone)]
pub struct RodeConfig {
    /// Runge–Kutta method (`method` key): any name or alias the method
    /// registry resolves — e.g. `dopri5`, `tsit5`, or the implicit
    /// `trbdf2` / `kvaerno43` for stiff workloads. `rode methods` lists
    /// everything registered.
    pub method: MethodId,
    /// Absolute tolerance (`atol` key).
    pub atol: f64,
    /// Relative tolerance (`rtol` key).
    pub rtol: f64,
    /// Dynamic-batcher flush size (`max_batch` key).
    pub max_batch: usize,
    /// Dynamic-batcher flush deadline (`max_wait_ms` key).
    pub max_wait: Duration,
    /// Solve engine: `native`, `joint` or `aot` (`engine` key).
    pub engine: String,
    /// Directory holding the AOT artifacts (`artifacts_dir` key).
    pub artifacts_dir: String,
    /// Worker threads for the native solve loops (0 = one per core).
    pub threads: usize,
    /// Worker-pool implementation (`pool` key:
    /// `serial` | `scoped` | `persistent`).
    pub pool: PoolKind,
    /// Rows per work-stealing chunk (`steal_chunk` key; 0 = heuristic,
    /// only meaningful with `pool = persistent`).
    pub steal_chunk: usize,
    /// Active-set compaction threshold for the parallel solve loops
    /// (`0.0` disables; see `SolveOptions::compact_threshold`).
    pub compact_threshold: f64,
    /// Workspace memory layout for the stage kernels (`layout` key:
    /// `row_major` | `dim_major`). Bitwise-identical results either way;
    /// see `SolveOptions::layout`.
    pub layout: Layout,
    /// Bound on admitted-but-unresolved service requests (`max_queue`
    /// key); submissions beyond it are shed with an `Overloaded` error.
    /// `0` = unbounded.
    pub max_queue: usize,
    /// Default per-request deadline (`deadline_ms` key); requests whose
    /// deadline passes before dispatch are dropped. Unset = no deadline.
    pub deadline: Option<Duration>,
    /// Jacobian-structure override for the implicit Newton path (`jac`
    /// key: `auto` | `dense` | `banded:KL,KU`). `auto` (the default)
    /// trusts each problem's own declaration; see
    /// `SolveOptions::jac_structure`.
    pub jac: Option<crate::problems::JacStructure>,
    /// Stiffness-escalation fallback method (`retry_method` key): any
    /// registry method name, or `off`/`none` to disable escalation.
    pub retry_method: Option<MethodId>,
    /// Escalation retries allowed per request (`max_retries` key).
    pub max_retries: u32,
    /// Coordinator worker threads (`workers` key): each runs its own
    /// engine + batcher; `0` = one per available core.
    pub workers: usize,
    /// Proactive stiffness classifier (`classifier` key): probe each
    /// admitted request's dominant eigenvalue and route stiff ones to
    /// the implicit fallback *before* the first solve.
    pub classifier: bool,
}

impl Default for RodeConfig {
    fn default() -> Self {
        Self {
            method: MethodId::DOPRI5,
            atol: 1e-6,
            rtol: 1e-5,
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            engine: "native".to_string(),
            artifacts_dir: "artifacts".to_string(),
            threads: 1,
            pool: PoolKind::Scoped,
            steal_chunk: 0,
            compact_threshold: 0.0,
            layout: Layout::default_from_env(),
            max_queue: 1024,
            deadline: None,
            jac: None,
            retry_method: Some(MethodId::TRBDF2),
            max_retries: 1,
            workers: 0,
            classifier: false,
        }
    }
}

impl RodeConfig {
    /// Build a config from parsed key/value pairs, validating every
    /// recognized key; unknown keys are ignored, unset keys keep their
    /// defaults.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(m) = raw.get("method") {
            cfg.method = MethodId::parse(m).ok_or_else(|| anyhow!("unknown method {m}"))?;
        }
        if let Some(v) = raw.get_f64("atol")? {
            cfg.atol = v;
        }
        if let Some(v) = raw.get_f64("rtol")? {
            cfg.rtol = v;
        }
        if let Some(v) = raw.get_usize("max_batch")? {
            cfg.max_batch = v;
        }
        if let Some(v) = raw.get_f64("max_wait_ms")? {
            cfg.max_wait = Duration::from_secs_f64(v / 1e3);
        }
        if let Some(v) = raw.get("engine") {
            cfg.engine = v.to_string();
        }
        if let Some(v) = raw.get("artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = raw.get_usize("threads")? {
            cfg.threads = v;
        }
        if let Some(v) = raw.get("pool") {
            cfg.pool = PoolKind::parse(v)
                .ok_or_else(|| anyhow!("unknown pool kind {v} (serial|scoped|persistent)"))?;
        }
        if let Some(v) = raw.get_usize("steal_chunk")? {
            cfg.steal_chunk = v;
        }
        if let Some(v) = raw.get_f64("compact_threshold")? {
            anyhow::ensure!(
                (0.0..=1.0).contains(&v),
                "compact_threshold must be in [0, 1], got {v}"
            );
            cfg.compact_threshold = v;
        }
        if let Some(v) = raw.get("layout") {
            cfg.layout = Layout::parse(v)
                .ok_or_else(|| anyhow!("unknown layout {v} (row_major|dim_major)"))?;
        }
        if let Some(v) = raw.get_usize("max_queue")? {
            cfg.max_queue = v;
        }
        if let Some(v) = raw.get_f64("deadline_ms")? {
            anyhow::ensure!(v > 0.0, "deadline_ms must be positive, got {v}");
            cfg.deadline = Some(Duration::from_secs_f64(v / 1e3));
        }
        if let Some(v) = raw.get("jac") {
            cfg.jac = match v.to_ascii_lowercase().as_str() {
                "auto" => None,
                s => Some(crate::problems::JacStructure::parse(s).ok_or_else(|| {
                    anyhow!("bad jac structure {v} (auto|dense|banded:KL,KU)")
                })?),
            };
        }
        if let Some(v) = raw.get("retry_method") {
            cfg.retry_method = match v.to_ascii_lowercase().as_str() {
                "off" | "none" => None,
                name => Some(
                    MethodId::parse(name)
                        .ok_or_else(|| anyhow!("unknown retry_method {name} (or off|none)"))?,
                ),
            };
        }
        if let Some(v) = raw.get_usize("max_retries")? {
            cfg.max_retries = u32::try_from(v)
                .map_err(|_| anyhow!("max_retries out of range: {v}"))?;
        }
        if let Some(v) = raw.get_usize("workers")? {
            cfg.workers = v;
        }
        if let Some(v) = raw.get_bool("classifier")? {
            cfg.classifier = v;
        }
        Ok(cfg)
    }

    /// Load and validate the config file at `path`.
    pub fn load(path: &str) -> Result<Self> {
        Self::from_raw(&RawConfig::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_toml_subset() {
        let raw = RawConfig::parse(
            "# service\n[service]\nmethod = \"tsit5\"\natol = 1e-7\nmax_batch = 32\nengine = aot\n",
        )
        .unwrap();
        let cfg = RodeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.method, MethodId::TSIT5);
        assert_eq!(cfg.atol, 1e-7);
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.engine, "aot");
        // Unset keys keep defaults.
        assert_eq!(cfg.rtol, 1e-5);
    }

    #[test]
    fn implicit_method_key_parses() {
        let cfg = RodeConfig::from_raw(&RawConfig::parse("method = trbdf2").unwrap()).unwrap();
        assert_eq!(cfg.method, MethodId::TRBDF2);
        assert!(cfg.method.is_implicit());
        // Aliases resolve through the registry too.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("method = kv43").unwrap()).unwrap();
        assert_eq!(cfg.method, MethodId::KVAERNO43);
        assert!(cfg.method.is_implicit());
    }

    #[test]
    fn rejects_bad_values() {
        let raw = RawConfig::parse("atol = banana").unwrap();
        assert!(RodeConfig::from_raw(&raw).is_err());
        assert!(RawConfig::parse("no equals sign here").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let raw = RawConfig::parse("\n# only comments\n\n").unwrap();
        assert!(raw.get("anything").is_none());
        let cfg = RodeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.method, MethodId::DOPRI5);
    }

    #[test]
    fn threads_key_parses() {
        let raw = RawConfig::parse("threads = 4").unwrap();
        let cfg = RodeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.threads, 4);
        // Default is the serial path.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.threads, 1);
    }

    #[test]
    fn compact_threshold_key_parses_and_validates() {
        let cfg =
            RodeConfig::from_raw(&RawConfig::parse("compact_threshold = 0.25").unwrap()).unwrap();
        assert_eq!(cfg.compact_threshold, 0.25);
        // Default: compaction off.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.compact_threshold, 0.0);
        // Out-of-range values are rejected, not clamped.
        assert!(RodeConfig::from_raw(&RawConfig::parse("compact_threshold = 1.5").unwrap())
            .is_err());
    }

    #[test]
    fn exec_policy_resolution() {
        assert_eq!(ExecPolicy::default().threads, 1);
        assert_eq!(ExecPolicy::default().pool, PoolKind::Scoped);
        assert_eq!(ExecPolicy::serial().effective_threads(), 1);
        assert_eq!(ExecPolicy::threads(3).effective_threads(), 3);
        assert_eq!(ExecPolicy::persistent(4).pool, PoolKind::Persistent);
        // 0 = auto: at least one worker, whatever the machine.
        assert!(ExecPolicy::threads(0).effective_threads() >= 1);
    }

    #[test]
    fn steal_chunk_resolution() {
        // Explicit chunk sizes are used as-is.
        let mut p = ExecPolicy::persistent(4);
        p.steal_chunk = 7;
        assert_eq!(p.effective_steal_chunk(256), 7);
        // The heuristic aims for ~4 chunks per worker and never yields 0.
        let p = ExecPolicy::persistent(4);
        assert_eq!(p.effective_steal_chunk(256), 16);
        assert_eq!(p.effective_steal_chunk(3), 1);
        assert_eq!(ExecPolicy::persistent(1).effective_steal_chunk(0), 1);
    }

    #[test]
    fn pool_kind_parse_roundtrip() {
        for k in [PoolKind::Serial, PoolKind::Scoped, PoolKind::Persistent] {
            assert_eq!(PoolKind::parse(k.name()), Some(k));
        }
        assert_eq!(PoolKind::parse("Persistent"), Some(PoolKind::Persistent));
        assert_eq!(PoolKind::parse("rayon"), None);
    }

    #[test]
    fn pool_keys_parse_and_validate() {
        let raw = RawConfig::parse("pool = persistent\nsteal_chunk = 8").unwrap();
        let cfg = RodeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.pool, PoolKind::Persistent);
        assert_eq!(cfg.steal_chunk, 8);
        // Defaults: scoped pool, heuristic chunking.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.pool, PoolKind::Scoped);
        assert_eq!(cfg.steal_chunk, 0);
        // Unknown kinds are rejected, not defaulted.
        assert!(RodeConfig::from_raw(&RawConfig::parse("pool = rayon").unwrap()).is_err());
    }

    #[test]
    fn layout_key_parses_and_validates() {
        let cfg = RodeConfig::from_raw(&RawConfig::parse("layout = dim_major").unwrap()).unwrap();
        assert_eq!(cfg.layout, Layout::DimMajor);
        let cfg = RodeConfig::from_raw(&RawConfig::parse("layout = row-major").unwrap()).unwrap();
        assert_eq!(cfg.layout, Layout::RowMajor);
        // Unknown layouts are rejected, not defaulted.
        assert!(RodeConfig::from_raw(&RawConfig::parse("layout = soa").unwrap()).is_err());
    }

    #[test]
    fn serving_keys_parse_and_validate() {
        let raw = RawConfig::parse(
            "max_queue = 256\ndeadline_ms = 50\nretry_method = kvaerno43\nmax_retries = 2",
        )
        .unwrap();
        let cfg = RodeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.max_queue, 256);
        assert_eq!(cfg.deadline, Some(Duration::from_millis(50)));
        assert_eq!(cfg.retry_method, Some(MethodId::KVAERNO43));
        assert_eq!(cfg.max_retries, 2);
        // Defaults: bounded queue, no deadline, trbdf2 escalation.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.max_queue, 1024);
        assert_eq!(cfg.deadline, None);
        assert_eq!(cfg.retry_method, Some(MethodId::TRBDF2));
        assert_eq!(cfg.max_retries, 1);
        // Escalation can be switched off entirely.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("retry_method = off").unwrap()).unwrap();
        assert_eq!(cfg.retry_method, None);
        // Bad values are rejected, not defaulted.
        assert!(RodeConfig::from_raw(&RawConfig::parse("deadline_ms = -5").unwrap()).is_err());
        assert!(RodeConfig::from_raw(&RawConfig::parse("retry_method = rk99").unwrap()).is_err());
    }

    #[test]
    fn jac_key_parses_and_validates() {
        use crate::problems::JacStructure;
        let cfg = RodeConfig::from_raw(&RawConfig::parse("jac = banded:1,1").unwrap()).unwrap();
        assert_eq!(cfg.jac, Some(JacStructure::Banded { lower: 1, upper: 1 }));
        let cfg = RodeConfig::from_raw(&RawConfig::parse("jac = dense").unwrap()).unwrap();
        assert_eq!(cfg.jac, Some(JacStructure::Dense));
        // `auto` and unset both mean "trust the problem's declaration".
        let cfg = RodeConfig::from_raw(&RawConfig::parse("jac = auto").unwrap()).unwrap();
        assert_eq!(cfg.jac, None);
        let cfg = RodeConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.jac, None);
        // Malformed structures are rejected, not defaulted.
        assert!(RodeConfig::from_raw(&RawConfig::parse("jac = banded:1").unwrap()).is_err());
        assert!(RodeConfig::from_raw(&RawConfig::parse("jac = sparse").unwrap()).is_err());
    }

    #[test]
    fn fleet_keys_parse_and_validate() {
        let raw = RawConfig::parse("workers = 4\nclassifier = true").unwrap();
        let cfg = RodeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.workers, 4);
        assert!(cfg.classifier);
        // Defaults: one worker per core, classifier off.
        let cfg = RodeConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.workers, 0);
        assert!(!cfg.classifier);
        // Bad values are rejected, not defaulted.
        assert!(RodeConfig::from_raw(&RawConfig::parse("workers = many").unwrap()).is_err());
        assert!(RodeConfig::from_raw(&RawConfig::parse("classifier = on").unwrap()).is_err());
    }

    #[test]
    fn bool_parsing() {
        let raw = RawConfig::parse("flag = true").unwrap();
        assert_eq!(raw.get_bool("flag").unwrap(), Some(true));
        let raw = RawConfig::parse("flag = yes").unwrap();
        assert!(raw.get_bool("flag").is_err());
    }
}
