//! The pool-determinism contract: the same batch solved through every
//! combination of pool kind (`serial`, `scoped`, `persistent`), thread
//! count and steal-chunk size produces **bitwise-identical**
//! trajectories, stats, statuses and traces. Scheduling — which worker
//! ran which rows, how many steals happened — must never leak into
//! results; it is only visible through `Solution::exec_stats`, which is
//! deliberately outside the bitwise contract.

use rode::bench::straggler_workload;
use rode::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
use rode::prelude::*;
use rode::problems::VdP;
use rode::tensor::BatchVec;

/// Full bitwise equality of two solutions (NaN-safe via bit comparison).
/// `exec_stats` is intentionally not compared — it records scheduling.
fn assert_bitwise(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.status, b.status, "{label}: status");
    assert_eq!(a.stats, b.stats, "{label}: stats");
    let (fa, fb) = (a.ys_flat(), b.ys_flat());
    assert_eq!(fa.len(), fb.len(), "{label}: ys length");
    for (idx, (x, y)) in fa.iter().zip(fb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: ys[{idx}] {x} vs {y}");
    }
    assert_eq!(a.trace, b.trace, "{label}: trace");
}

const THREADS: [usize; 4] = [1, 2, 4, 7];
const POOLS: [PoolKind; 2] = [PoolKind::Scoped, PoolKind::Persistent];
const CHUNKS: [usize; 4] = [0, 1, 5, 16];

/// The parallel loop across the full matrix, on the straggler batch the
/// stealing pool exists for (one stiff row, many easy rows).
#[test]
fn parallel_bitwise_across_pools_threads_and_chunks() {
    let (sys, y0, grid) = straggler_workload(24, 40.0, 0.5, 5.0, 8);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(1_000_000)
        .with_trace();
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert!(serial.all_success());
    for threads in THREADS {
        for kind in POOLS {
            for chunk in CHUNKS {
                let opts = base
                    .clone()
                    .with_threads(threads)
                    .with_pool(kind)
                    .with_steal_chunk(chunk);
                let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(
                    &serial,
                    &got,
                    &format!("parallel {kind:?} threads={threads} chunk={chunk}"),
                );
            }
        }
    }
}

/// The joint loop (shared controller + fused norm) across the matrix:
/// the per-row norm partials may be computed by any worker, but the
/// row-order reduction keeps the shared controller decisions — and hence
/// everything downstream — bitwise-identical.
#[test]
fn joint_bitwise_across_pools_threads_and_chunks() {
    let mus = vec![1.0, 12.0, 3.0, 25.0, 0.7, 6.0, 2.0, 9.0, 1.5, 4.0];
    let b = mus.len();
    let sys = VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, 8.0, 12);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(1_000_000)
        .with_trace();
    let serial = solve_ivp_joint(&sys, &y0, &grid, &base);
    assert!(serial.all_success());
    for threads in THREADS {
        for kind in POOLS {
            for chunk in CHUNKS {
                let opts = base
                    .clone()
                    .with_threads(threads)
                    .with_pool(kind)
                    .with_steal_chunk(chunk);
                let got = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(
                    &serial,
                    &got,
                    &format!("joint {kind:?} threads={threads} chunk={chunk}"),
                );
            }
        }
    }
}

/// Non-FSAL methods exercise the accept-refresh entry of the call
/// ledger; its per-iteration max must be invariant to the partition —
/// contiguous shards and steal-chunks alike.
#[test]
fn non_fsal_ledger_invariant_to_partition() {
    let sys = VdP::new(vec![0.5, 8.0, 2.0, 5.0, 0.8, 3.0, 1.2]);
    let y0 = BatchVec::from_rows(
        &(0..7).map(|i| vec![1.0 + 0.1 * i as f64, 0.0]).collect::<Vec<_>>(),
    );
    let grid = TimeGrid::linspace_shared(7, 0.0, 4.0, 9);
    for m in [MethodId::FEHLBERG45, MethodId::HEUN] {
        let base = SolveOptions::new(m).with_tols(1e-6, 1e-6).with_max_steps(1_000_000);
        let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
        for (threads, chunk) in [(2, 1), (4, 2), (3, 0)] {
            let opts = base
                .clone()
                .with_threads(threads)
                .with_pool(PoolKind::Persistent)
                .with_steal_chunk(chunk);
            let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(&serial, &got, &format!("{m:?} threads={threads} chunk={chunk}"));
        }
    }
}

/// The implicit (TR-BDF2) method through the parallel matrix: per-row
/// Newton state (Jacobian/LU reuse, divergence history) is slot-local,
/// so trajectories, traces and every `Stats` counter — including the
/// Newton accounting `n_f_evals`/`n_jac_evals`/`n_lu_factor` — must be
/// bitwise-identical across pool kind × threads × steal-chunk.
#[test]
fn implicit_parallel_bitwise_across_pools_threads_and_chunks() {
    let (sys, y0, grid) = straggler_workload(16, 200.0, 0.5, 5.0, 6);
    let base = SolveOptions::new(MethodId::TRBDF2)
        .with_tols(1e-6, 1e-4)
        .with_max_steps(1_000_000)
        .with_trace();
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert!(serial.all_success());
    // Per-row accounting really is per-row: the stiff straggler did more
    // Newton work than its easy neighbors.
    assert!(serial.stats[0].n_f_evals > serial.stats[1].n_f_evals);
    assert!(serial.stats[0].n_jac_evals > 0);
    for threads in [2, 4, 7] {
        for kind in POOLS {
            for chunk in [0, 3] {
                let opts = base
                    .clone()
                    .with_threads(threads)
                    .with_pool(kind)
                    .with_steal_chunk(chunk);
                let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(
                    &serial,
                    &got,
                    &format!("implicit parallel {kind:?} threads={threads} chunk={chunk}"),
                );
            }
        }
    }
}

/// The implicit method through the joint matrix: the sharded executors
/// split the Newton scratch per range exactly like the stage buffers,
/// and a Newton divergence (a shared reject) is a per-row property, so
/// the shared controller sequence — and everything downstream — is
/// bitwise-identical whatever carried the passes.
#[test]
fn implicit_joint_bitwise_across_pools_threads_and_chunks() {
    let mus = vec![1.0, 60.0, 3.0, 25.0, 0.7, 120.0, 2.0, 9.0];
    let b = mus.len();
    let sys = VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, 6.0, 8);
    let base = SolveOptions::new(MethodId::TRBDF2)
        .with_tols(1e-6, 1e-4)
        .with_max_steps(1_000_000)
        .with_trace();
    let serial = solve_ivp_joint(&sys, &y0, &grid, &base);
    assert!(serial.all_success());
    for threads in [2, 4] {
        for kind in POOLS {
            for chunk in [0, 3] {
                let opts = base
                    .clone()
                    .with_threads(threads)
                    .with_pool(kind)
                    .with_steal_chunk(chunk);
                let got = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(
                    &serial,
                    &got,
                    &format!("implicit joint {kind:?} threads={threads} chunk={chunk}"),
                );
            }
        }
    }
}

/// Pool selection is observable: the quiet serial fallback, the scoped
/// path and the persistent path each stamp `exec_stats` — no more
/// guessing whether a "pooled" solve actually pooled.
#[test]
fn pool_kind_is_observable_in_exec_stats() {
    let (sys, y0, grid) = straggler_workload(12, 20.0, 0.5, 4.0, 6);
    let base = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(1_000_000);

    // threads = 1: the pooled entry quietly runs serially — and says so.
    let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(1));
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Serial);
    assert_eq!(sol.exec_stats.threads, 1);
    assert_eq!(sol.exec_stats.steal_count, 0);

    // An explicit serial policy forces the fallback at any thread count.
    let sol = solve_ivp_parallel_pooled(
        &sys,
        &y0,
        &grid,
        &base.clone().with_threads(4).with_pool(PoolKind::Serial),
    );
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Serial);

    // The scoped path really is exercised (not silently degraded).
    let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(4));
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Scoped);
    assert_eq!(sol.exec_stats.threads, 4);
    assert_eq!(sol.exec_stats.shards, 4);
    assert_eq!(sol.exec_stats.steal_count, 0, "scoped pool never steals");

    // The persistent path records its chunking; with chunk = 1 row the
    // shard count equals the batch.
    let opts = base
        .clone()
        .with_threads(4)
        .with_pool(PoolKind::Persistent)
        .with_steal_chunk(1);
    let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Persistent);
    assert_eq!(sol.exec_stats.threads, 4);
    assert_eq!(sol.exec_stats.shards, 12);

    // Joint entry points stamp the same way.
    let jgrid = TimeGrid::linspace_shared(12, 0.0, 4.0, 6);
    let sol = solve_ivp_joint_pooled(&sys, &y0, &jgrid, &base.clone().with_threads(2));
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Scoped);
    let sol = solve_ivp_joint_pooled(
        &sys,
        &y0,
        &jgrid,
        &base.clone().with_threads(2).with_pool(PoolKind::Persistent),
    );
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Persistent);
    let sol = solve_ivp_joint_pooled(&sys, &y0, &jgrid, &base.clone().with_threads(1));
    assert_eq!(sol.exec_stats.pool_kind, PoolKind::Serial);
}

/// An oversubscribed stealing pool (threads and chunks both exceeding
/// any useful parallelism) stays safe and bitwise-correct.
#[test]
fn oversubscribed_stealing_pool_is_safe() {
    let (sys, y0, grid) = straggler_workload(3, 20.0, 0.5, 4.0, 6);
    let base = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(1_000_000);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    let opts =
        base.clone().with_threads(16).with_pool(PoolKind::Persistent).with_steal_chunk(1);
    let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
    assert_bitwise(&serial, &got, "oversubscribed persistent");
    // Workers are capped by the chunk count.
    assert_eq!(got.exec_stats.threads, 3);
    assert_eq!(got.exec_stats.shards, 3);
}

/// Stealing composes with compaction and `eval_inactive = false` — the
/// straggler chunk packs its own state while its neighbors get stolen.
#[test]
fn stealing_composes_with_compaction() {
    let (sys, y0, grid) = straggler_workload(16, 40.0, 0.5, 5.0, 8);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(1_000_000)
        .skip_inactive()
        .with_compaction(0.5);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    for chunk in [2, 4] {
        let opts = base
            .clone()
            .with_threads(4)
            .with_pool(PoolKind::Persistent)
            .with_steal_chunk(chunk);
        let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
        assert_bitwise(&serial, &got, &format!("compaction chunk={chunk}"));
    }
}
