//! End-to-end AOT path: `make artifacts` output → PJRT compile → execute →
//! numerics match the native Rust solver on the same problems.
//!
//! These tests skip (pass trivially) when `artifacts/` has not been built,
//! so `cargo test` stays green pre-`make artifacts`; `make test` always
//! builds artifacts first.

use rode::prelude::*;
use rode::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn solve_artifact_matches_native_solver() {
    let Some(mut rt) = runtime() else { return };
    let art = rt.load("solve_vdp_b8_e20").expect("load artifact");
    let (b, e) = (8, 20);
    let mus: Vec<f64> = (0..b).map(|i| 1.0 + i as f64).collect();
    let t1 = 5.0;

    // AOT solve (f32).
    let mut y0 = vec![0f32; b * 2];
    for i in 0..b {
        y0[i * 2] = 2.0;
    }
    let mu32: Vec<f32> = mus.iter().map(|&m| m as f32).collect();
    let te: Vec<f32> = (0..b)
        .flat_map(|_| (0..e).map(|k| (t1 * k as f64 / (e - 1) as f64) as f32))
        .collect();
    let out = art.run_f32(&[&y0, &mu32, &te]).expect("run");
    let ys = &out[0];
    let status = &out[4];
    assert!(status.iter().all(|&s| s == 0.0), "AOT statuses: {status:?}");

    // Native solve (f64) at the same tolerances.
    let sys = rode::problems::VdP::new(mus);
    let y0n = BatchVec::broadcast(&[2.0, 0.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, t1, e);
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
    let sol = solve_ivp_parallel(&sys, &y0n, &grid, &opts);
    assert!(sol.all_success());

    // Trajectories agree to solver tolerance (f32 AOT vs f64 native, both
    // at atol=rtol=1e-5; VdP trajectories are O(1), so 5e-3 is generous
    // but catches any structural disagreement).
    let mut max_diff = 0f64;
    for i in 0..b {
        for ev in 0..e {
            for d in 0..2 {
                let a = ys[(i * e + ev) * 2 + d] as f64;
                let n = sol.y(i, ev)[d];
                max_diff = max_diff.max((a - n).abs());
            }
        }
    }
    assert!(max_diff < 5e-3, "AOT vs native max diff {max_diff}");
}

#[test]
fn step_artifact_agrees_with_native_step() {
    let Some(mut rt) = runtime() else { return };
    let art = rt.load("step_vdp_b8").expect("load");
    let b = 8;
    let mu = 2.0f64;

    // Native single attempt.
    let sys = rode::problems::VdP::uniform(b, mu);
    let ct = rode::solver::step::CompiledTableau::new(MethodId::DOPRI5.tableau());
    let mut ws = rode::solver::step::RkWorkspace::new(7, b, 2);
    let y = BatchVec::broadcast(&[2.0, 0.0], b);
    let t = vec![0.0; b];
    let dt = vec![0.01; b];
    let k0_ready = vec![false; b];
    rode::solver::step::rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws, &k0_ready, None, true);

    // AOT attempt with the same k0.
    let dt32 = vec![0.01f32; b];
    let y32: Vec<f32> = y.flat().iter().map(|&v| v as f32).collect();
    let k032: Vec<f32> = ws.k[0].flat().iter().map(|&v| v as f32).collect();
    let mu32 = vec![mu as f32; b];
    let out = art.run_f32(&[&dt32, &y32, &k032, &mu32]).expect("run");
    let y_new = &out[0];
    for i in 0..b {
        for d in 0..2 {
            let a = y_new[i * 2 + d] as f64;
            let n = ws.y_new.row(i)[d];
            assert!((a - n).abs() < 1e-5, "i={i} d={d}: {a} vs {n}");
        }
    }
    // Error norms match to f32 precision.
    let en_native = rode::solver::norm::scaled_norm(
        rode::solver::norm::NormKind::Rms,
        ws.err.row(0),
        y.row(0),
        ws.y_new.row(0),
        1e-5,
        1e-5,
    );
    assert!((out[1][0] as f64 - en_native).abs() < 1e-3 * (1.0 + en_native));
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(mut rt) = runtime() else { return };
    let t0 = std::time::Instant::now();
    let _a = rt.load("solve_vdp_b8_e20").expect("load");
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _b = rt.load("solve_vdp_b8_e20").expect("load cached");
    let warm = t1.elapsed();
    assert!(warm < cold / 10, "cache miss? cold={cold:?} warm={warm:?}");
}

#[test]
fn per_instance_steps_visible_through_aot() {
    // The stiff instance takes more steps *inside* the compiled module —
    // per-instance state survives AOT lowering.
    let Some(mut rt) = runtime() else { return };
    let art = rt.load("solve_vdp_b8_e20").expect("load");
    let (b, e) = (8, 20);
    let mut y0 = vec![0f32; b * 2];
    for i in 0..b {
        y0[i * 2] = 2.0;
    }
    let mu32: Vec<f32> = (0..b).map(|i| 1.0 + 3.0 * i as f32).collect();
    let te: Vec<f32> = (0..b)
        .flat_map(|_| (0..e).map(|k| 8.0 * k as f32 / (e - 1) as f32))
        .collect();
    let out = art.run_f32(&[&y0, &mu32, &te]).expect("run");
    let n_steps = &out[1];
    assert!(
        n_steps[b - 1] > n_steps[0],
        "stiff instance should take more steps: {n_steps:?}"
    );
}
