//! Property-based integration tests over the solver invariants, using the
//! in-crate `rode::prop` harness (seeded, replayable cases).

use rode::prelude::*;
use rode::prop;
use rode::tensor::BatchVec;

/// Every adaptive method must hit the exact solution of a random linear
/// 2-D system within tolerance, for random initial conditions and spans.
#[test]
fn prop_adaptive_methods_solve_linear_systems() {
    prop::check("linear-accuracy", 25, 101, |rng| {
        let decay = rng.range(0.0, 1.5);
        let omega = rng.range(0.5, 4.0);
        let sys = rode::problems::LinearSystem::damped_rotation(decay, omega);
        let y0v = [rng.range(-2.0, 2.0), rng.range(-2.0, 2.0)];
        let t1 = rng.range(0.5, 4.0);
        let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
        let grid = TimeGrid::linspace_shared(1, 0.0, t1, 5);
        let m = [MethodId::BOSH3, MethodId::DOPRI5, MethodId::TSIT5, MethodId::CASHKARP45]
            [rng.below(4)];
        let opts = SolveOptions::new(m).with_tols(1e-8, 1e-8).with_max_steps(100_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success(), "{m:?} {decay} {omega}");
        let mut exact = [0.0; 2];
        rode::problems::LinearSystem::damped_rotation_exact(decay, omega, &y0v, t1, &mut exact);
        for d in 0..2 {
            assert!(
                (sol.y_final(0)[d] - exact[d]).abs() < 1e-5 * (1.0 + exact[d].abs()),
                "{m:?}: {} vs {}",
                sol.y_final(0)[d],
                exact[d]
            );
        }
    });
}

/// Instance isolation: an instance's trajectory and step count must be
/// bit-identical whatever batch it is embedded in (the torchode
/// guarantee that §4.1 is about).
#[test]
fn prop_instance_isolation_under_batching() {
    prop::check("instance-isolation", 15, 202, |rng| {
        let mu = rng.range(0.5, 8.0);
        let y0v = vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)];
        let t1 = rng.range(2.0, 6.0);
        let n_eval = 3 + rng.below(8);

        let solo = {
            let sys = rode::problems::VdP::new(vec![mu]);
            let y0 = BatchVec::from_rows(&[y0v.clone()]);
            let grid = TimeGrid::linspace_shared(1, 0.0, t1, n_eval);
            let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6);
            solve_ivp_parallel(&sys, &y0, &grid, &opts)
        };

        // Embed among 1..6 random companions.
        let extra = 1 + rng.below(5);
        let mut mus = vec![mu];
        let mut rows = vec![y0v.clone()];
        for _ in 0..extra {
            mus.push(rng.range(0.5, 40.0));
            rows.push(vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)]);
        }
        let sys = rode::problems::VdP::new(mus);
        let y0 = BatchVec::from_rows(&rows);
        let grid = TimeGrid::linspace_shared(1 + extra, 0.0, t1, n_eval);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6);
        let mixed = solve_ivp_parallel(&sys, &y0, &grid, &opts);

        assert_eq!(mixed.status[0], solo.status[0]);
        assert_eq!(mixed.stats[0].n_steps, solo.stats[0].n_steps);
        assert_eq!(mixed.stats[0].n_accepted, solo.stats[0].n_accepted);
        for e in 0..n_eval {
            for d in 0..2 {
                assert_eq!(mixed.y(0, e)[d], solo.y(0, e)[d], "e={e} d={d}");
            }
        }
    });
}

/// Stats invariants: accepted ≤ steps, n_initialized == n_eval on
/// success, f_evals uniform across the batch, and the dense outputs
/// contain no NaNs for successful instances.
#[test]
fn prop_stats_invariants() {
    prop::check("stats-invariants", 20, 303, |rng| {
        let batch = 1 + rng.below(6);
        let mus: Vec<f64> = (0..batch).map(|_| rng.range(0.3, 12.0)).collect();
        let sys = rode::problems::VdP::new(mus);
        let y0 = BatchVec::from_rows(
            &(0..batch)
                .map(|_| vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)])
                .collect::<Vec<_>>(),
        );
        let n_eval = 2 + rng.below(20);
        let grid = TimeGrid::linspace_shared(batch, 0.0, rng.range(1.0, 8.0), n_eval);
        let m = [MethodId::DOPRI5, MethodId::TSIT5, MethodId::BOSH3][rng.below(3)];
        let opts = SolveOptions::new(m).with_tols(1e-5, 1e-5).with_max_steps(100_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        let f0 = sol.stats[0].n_f_evals;
        for i in 0..batch {
            let st = &sol.stats[i];
            assert!(st.n_accepted <= st.n_steps);
            assert_eq!(st.n_f_evals, f0, "f_evals must be uniform");
            if sol.status[i] == Status::Success {
                assert_eq!(st.n_initialized as usize, n_eval);
                for e in 0..n_eval {
                    assert!(sol.y(i, e).iter().all(|v| v.is_finite()), "i={i} e={e}");
                }
            }
        }
    });
}

/// Dense output consistency: every interpolated point of a successful
/// solve must agree with an independent solve that puts an eval point
/// exactly there (within interpolation order of the tolerance).
#[test]
fn prop_dense_output_consistency() {
    prop::check("dense-output", 10, 404, |rng| {
        let lam = rng.range(0.2, 3.0);
        let sys = rode::problems::ExponentialDecay::new(vec![lam], 2);
        let y0 = BatchVec::from_rows(&[vec![rng.range(0.5, 2.0), rng.range(-2.0, -0.5)]]);
        let t1 = rng.range(1.0, 4.0);
        let n_eval = 4 + rng.below(12);
        let grid = TimeGrid::linspace_shared(1, 0.0, t1, n_eval);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for e in 0..n_eval {
            let t = grid.row(0)[e];
            let scale = (-lam * t).exp();
            for d in 0..2 {
                let exact = y0.row(0)[d] * scale;
                assert!(
                    (sol.y(0, e)[d] - exact).abs() < 1e-5 * (1.0 + exact.abs()),
                    "e={e}: {} vs {exact}",
                    sol.y(0, e)[d]
                );
            }
        }
    });
}

/// Joint and naive engines implement the same semantics: equal step
/// counts (±10 %) and matching trajectories on random batches.
#[test]
fn prop_joint_naive_equivalence() {
    prop::check("joint-naive", 10, 505, |rng| {
        let batch = 1 + rng.below(4);
        let mus: Vec<f64> = (0..batch).map(|_| rng.range(0.5, 6.0)).collect();
        let sys = rode::problems::VdP::new(mus);
        let y0 = BatchVec::broadcast(&[rng.range(0.5, 2.0), 0.0], batch);
        let grid = TimeGrid::linspace_shared(batch, 0.0, rng.range(2.0, 5.0), 6);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6);
        let a = solve_ivp_joint(&sys, &y0, &grid, &opts);
        let b = solve_ivp_naive(&sys, &y0, &grid, &opts);
        assert!(a.all_success() && b.all_success());
        let (sa, sb) = (a.stats[0].n_steps as f64, b.stats[0].n_steps as f64);
        assert!((sa - sb).abs() <= 0.1 * sa.max(sb) + 1.0, "steps {sa} vs {sb}");
        for i in 0..batch {
            for d in 0..2 {
                assert!(
                    (a.y_final(i)[d] - b.y_final(i)[d]).abs() < 1e-3,
                    "i={i} d={d}"
                );
            }
        }
    });
}

/// Empirical order of convergence of the implicit TR-BDF2 pair on a
/// smooth nonlinear problem (Lotka–Volterra): with fixed steps the
/// global error must shrink like h² — the observed order from two
/// refinements must sit within tolerance of the design order 2. Newton
/// is solved far below the measurement floor (tols 1e-12 make the
/// convergence threshold ~1e-13), so the slope measures the
/// discretization, not the nonlinear solver.
#[test]
fn trbdf2_observed_order_matches_design_order() {
    let sys = rode::problems::LotkaVolterra::uniform(1, 1.1, 0.4, 0.1, 0.4);
    let y0 = BatchVec::from_rows(&[vec![2.0, 1.0]]);
    let grid = TimeGrid::linspace_shared(1, 0.0, 2.0, 2);
    let solve_fixed = |h: f64| -> Vec<f64> {
        let opts = SolveOptions::new(MethodId::TRBDF2)
            .with_tols(1e-12, 1e-12)
            .with_fixed_dt(h)
            .with_max_steps(100_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success(), "h={h}");
        sol.y_final(0).to_vec()
    };
    let reference = solve_fixed(0.003125);
    let err = |y: &[f64]| -> f64 {
        y.iter().zip(&reference).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt()
    };
    let e1 = err(&solve_fixed(0.05));
    let e2 = err(&solve_fixed(0.025));
    let e3 = err(&solve_fixed(0.0125));
    let order_a = (e1 / e2).log2();
    let order_b = (e2 / e3).log2();
    assert!(
        (1.7..=2.4).contains(&order_a) && (1.7..=2.4).contains(&order_b),
        "observed orders {order_a:.2}, {order_b:.2} (errors {e1:.3e}, {e2:.3e}, {e3:.3e})"
    );
}

/// Linear-problem sanity for the implicit pair: (a) L-stability smoke —
/// on y' = λy with λ = −10⁶, steps of size 1 (hλ = −10⁶) stay bounded
/// and decaying; (b) exactness regime — at small hλ the fixed-step
/// solution tracks exp(λt) with the h² global error of the trapezoidal
/// substage.
#[test]
fn trbdf2_linear_l_stability_and_small_h_accuracy() {
    // (a) One-step-per-unit integration of a brutally stiff decay.
    let sys = rode::problems::ExponentialDecay::new(vec![1e6], 1);
    let y0 = BatchVec::from_rows(&[vec![1.0]]);
    let grid = TimeGrid::linspace_shared(1, 0.0, 3.0, 4);
    let opts = SolveOptions::new(MethodId::TRBDF2)
        .with_tols(1e-8, 1e-8)
        .with_fixed_dt(1.0)
        .with_max_steps(100);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success(), "{:?}", sol.status);
    let mut prev = 1.0f64;
    for e in 1..4 {
        let v = sol.y(0, e)[0];
        assert!(v.is_finite() && v.abs() <= prev, "e={e}: |{v}| > {prev}");
        prev = v.abs();
    }
    // L-stable damping: after one huge step the fast mode is essentially
    // gone (an A-stable-only trapezoid would leave |y| ≈ |y0|).
    assert!(sol.y(0, 1)[0].abs() < 1e-2, "fast mode survived: {}", sol.y(0, 1)[0]);

    // (b) Small-h accuracy on y' = −y.
    let sys = rode::problems::ExponentialDecay::new(vec![1.0], 1);
    let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 2);
    let opts = SolveOptions::new(MethodId::TRBDF2)
        .with_tols(1e-12, 1e-12)
        .with_fixed_dt(0.01)
        .with_max_steps(1_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success());
    let err = (sol.y_final(0)[0] - (-1.0f64).exp()).abs();
    assert!(err < 1e-5, "fixed-step error {err} too large for h=0.01");
}

/// Adjoint gradients match finite differences for random VdP problems.
#[test]
fn prop_adjoint_gradients_match_fd() {
    prop::check("adjoint-fd", 6, 606, |rng| {
        let mu = rng.range(0.3, 2.0);
        let tt = rng.range(0.5, 2.0);
        let y0v = [rng.range(-1.5, 1.5), rng.range(-1.0, 1.0)];
        let run = |mu: f64, y0v: [f64; 2]| -> f64 {
            let sys = rode::problems::VdP::new(vec![mu]);
            let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
            let grid = TimeGrid::linspace_shared(1, 0.0, tt, 2);
            let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            sol.y_final(0)[0]
        };
        let sys = rode::problems::VdP::new(vec![mu]);
        let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
        let grid = TimeGrid::linspace_shared(1, 0.0, tt, 2);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        let mut y1 = BatchVec::zeros(1, 2);
        y1.row_mut(0).copy_from_slice(sol.y_final(0));
        let dl = BatchVec::from_rows(&[vec![1.0, 0.0]]);
        let res = rode::solver::adjoint_backward_parallel(
            &sys,
            &y1,
            &dl,
            &[0.0],
            &[tt],
            &rode::solver::AdjointOptions::new(
                SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10),
            ),
        );
        let h = 1e-5;
        let fd_mu = (run(mu + h, y0v) - run(mu - h, y0v)) / (2.0 * h);
        assert!(
            (res.dl_dparams[0] - fd_mu).abs() < 2e-4 * (1.0 + fd_mu.abs()),
            "mu-grad {} vs fd {fd_mu}",
            res.dl_dparams[0]
        );
    });
}
