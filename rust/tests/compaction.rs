//! The active-set / compaction contract: for every method shape (FSAL,
//! non-FSAL, fixed-step, per-instance tolerances), with and without
//! overhanging evaluations, at every compaction threshold, and through
//! the pooled exec paths, the solve is **bitwise-identical** — solutions,
//! stats (including `n_f_evals`), statuses and traces — to the frozen
//! mask-based reference loop (`rode::solver::reference`).

use rode::bench::straggler_workload;
use rode::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
use rode::prelude::*;
use rode::solver::reference::solve_ivp_parallel_reference;
use rode::solver::Tolerances;
use rode::tensor::BatchVec;

/// Full bitwise equality of two solutions (NaN-safe via bit comparison).
fn assert_bitwise(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.status, b.status, "{label}: status");
    assert_eq!(a.stats, b.stats, "{label}: stats");
    let (fa, fb) = (a.ys_flat(), b.ys_flat());
    assert_eq!(fa.len(), fb.len(), "{label}: ys length");
    for (idx, (x, y)) in fa.iter().zip(fb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: ys[{idx}] {x} vs {y}");
    }
    assert_eq!(a.trace, b.trace, "{label}: trace");
}

/// The straggler batch: one stiff VdP row + easy rows that finish early,
/// so compaction actually fires at every nonzero threshold.
fn workload(batch: usize) -> (rode::problems::VdP, BatchVec, TimeGrid) {
    straggler_workload(batch, 40.0, 0.5, 5.0, 10)
}

/// FSAL (dopri5 dense) and non-FSAL (Hermite dense) adaptive methods,
/// both eval modes, thresholds from "never" to "eagerly": all bitwise
/// equal to the reference loop.
#[test]
fn active_set_matches_reference_across_methods_and_thresholds() {
    let (sys, y0, grid) = workload(12);
    for m in [MethodId::DOPRI5, MethodId::TSIT5, MethodId::FEHLBERG45] {
        let base = SolveOptions::new(m)
            .with_tols(1e-6, 1e-6)
            .with_max_steps(1_000_000)
            .with_trace();
        for eval_inactive in [true, false] {
            let mut opts = base.clone();
            opts.eval_inactive = eval_inactive;
            let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &opts);
            assert!(reference.all_success(), "{m:?}");
            for threshold in [0.0, 0.3, 0.75, 1.0] {
                let copts = opts.clone().with_compaction(threshold);
                let got = solve_ivp_parallel(&sys, &y0, &grid, &copts);
                assert_bitwise(
                    &reference,
                    &got,
                    &format!("{m:?} eval_inactive={eval_inactive} threshold={threshold}"),
                );
            }
        }
    }
}

/// Fixed-step methods drive the non-adaptive path (no controller, no
/// rejections) through compaction.
#[test]
fn fixed_step_matches_reference_under_compaction() {
    let (sys, y0, grid) = workload(6);
    let base = SolveOptions::new(MethodId::RK4).with_fixed_dt(1e-3).with_max_steps(20_000);
    let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &base);
    let got = solve_ivp_parallel(&sys, &y0, &grid, &base.clone().with_compaction(1.0));
    assert_bitwise(&reference, &got, "rk4 fixed-step");
}

/// Per-instance tolerance vectors index by *original row*; compaction
/// must keep routing each packed slot to its own tolerances.
#[test]
fn per_instance_tolerances_survive_compaction() {
    let (sys, y0, grid) = workload(6);
    let mut base = SolveOptions::new(MethodId::DOPRI5).with_max_steps(1_000_000);
    base.tols = Tolerances::per_instance(
        vec![1e-5, 1e-7, 1e-6, 1e-8, 1e-5, 1e-6],
        vec![1e-5, 1e-7, 1e-6, 1e-8, 1e-5, 1e-6],
    );
    let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &base);
    for threshold in [0.5, 1.0] {
        let got = solve_ivp_parallel(&sys, &y0, &grid, &base.clone().with_compaction(threshold));
        assert_bitwise(&reference, &got, &format!("per-instance tols, threshold={threshold}"));
    }
}

/// Rows that fail (max-steps) stay bitwise-faithful while their easy
/// batchmates are compacted away around them.
#[test]
fn failing_straggler_matches_reference_under_compaction() {
    // Easy rows (µ = 0.5, tol 1e-6) finish within ~200 steps, so
    // compaction actually fires before the stiff row hits the cap.
    let (sys, y0, grid) = straggler_workload(5, 1000.0, 0.5, 10.0, 8);
    let base = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(400);
    let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &base);
    assert_eq!(reference.status[0], Status::MaxStepsReached);
    let got = solve_ivp_parallel(&sys, &y0, &grid, &base.clone().with_compaction(1.0));
    assert_bitwise(&reference, &got, "max-steps straggler");
}

/// The pooled parallel path: every shard (scoped) or steal-chunk
/// (persistent) runs the active-set loop with compaction independently;
/// the merged result must still equal the serial reference bitwise,
/// including the uniform `n_f_evals`.
#[test]
fn pooled_parallel_with_compaction_matches_reference() {
    let (sys, y0, grid) = workload(12);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(1_000_000)
        .with_trace()
        .skip_inactive();
    let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &base);
    for threads in [2, 3, 4] {
        for kind in [PoolKind::Scoped, PoolKind::Persistent] {
            let opts = base
                .clone()
                .with_threads(threads)
                .with_pool(kind)
                .with_compaction(0.5);
            let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(&reference, &got, &format!("pooled {kind:?} threads={threads}"));
        }
    }
}

/// The joint pooled path is untouched by compaction (one shared state),
/// but its loop internals changed (hoisted buffers, pending-cursor active
/// set, fused error-norm partials) — it must still match the serial
/// joint loop bitwise on both pool kinds.
#[test]
fn joint_pooled_still_matches_serial_bitwise() {
    let mus = vec![1.0, 6.0, 2.0, 12.0];
    let b = mus.len();
    let sys = rode::problems::VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, 8.0, 15);
    for m in [MethodId::DOPRI5, MethodId::FEHLBERG45] {
        let base = SolveOptions::new(m)
            .with_tols(1e-6, 1e-6)
            .with_max_steps(1_000_000)
            .with_trace()
            .with_compaction(0.5); // must be a no-op for joint solving
        let serial = solve_ivp_joint(&sys, &y0, &grid, &base);
        assert!(serial.all_success());
        for threads in [2, 4] {
            for kind in [PoolKind::Scoped, PoolKind::Persistent] {
                let opts = base.clone().with_threads(threads).with_pool(kind);
                let got = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(&serial, &got, &format!("joint {m:?} {kind:?} t={threads}"));
            }
        }
    }
}

/// The `scaled_norm` 0/0 fix end to end: a zero state with `atol = 0`
/// takes exact steps (`err = 0`) and must accept them instead of
/// reject-hard riding into `DtUnderflow`.
#[test]
fn zero_state_with_zero_atol_succeeds() {
    let sys = rode::problems::ExponentialDecay::new(vec![1.0, 1.0], 1);
    let y0 = BatchVec::from_rows(&[vec![0.0], vec![0.0]]);
    let grid = TimeGrid::linspace_shared(2, 0.0, 1.0, 5);
    let mut opts = SolveOptions::new(MethodId::DOPRI5).with_max_steps(10_000);
    opts.tols = Tolerances::per_instance(vec![0.0, 0.0], vec![1e-6, 1e-6]);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success(), "{:?}", sol.status);
    for e in 0..5 {
        assert_eq!(sol.y(0, e)[0], 0.0);
    }
}
