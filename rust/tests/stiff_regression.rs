//! The stiff-regression suite: the implicit method under test (TR-BDF2 by
//! default, any registered implicit method via `RODE_STIFF_METHOD`, e.g.
//! `RODE_STIFF_METHOD=kvaerno43` in CI) must solve
//! the workloads that defined the explicit solver's wall — Van der Pol
//! at μ up to 5000 and the Robertson kinetics problem — while explicit
//! Dopri5 at μ = 1000 is pinned to still hit `DtUnderflow` (the wall the
//! implicit method removes). The acceptance batch (256 rows, one μ=1000
//! straggler among easy rows) must reach `Status::Success` in both the
//! parallel and joint loops, bitwise-identical across pool kinds,
//! steal-chunk sizes, layouts and compaction, with the per-row Newton
//! accounting (`n_f_evals`, `n_jac_evals`, `n_lu_factor`) exact under
//! sharded merges — `Stats` equality below covers all counters.

use rode::bench::vdp_stiff_span;
use rode::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
use rode::prelude::*;
use rode::problems::{ReactionDiffusion, Robertson, VdP};
use rode::tensor::BatchVec;

/// The implicit method under test. Defaults to TR-BDF2; CI re-runs the
/// suite with `RODE_STIFF_METHOD=kvaerno43` so every stiff method in the
/// registry clears the same bar. Tests pinning a *specific* method's
/// behavior (the Dopri5 stability wall, the trapezoidal-stage divergence
/// probe) ignore the variable.
fn stiff_method() -> MethodId {
    match std::env::var("RODE_STIFF_METHOD") {
        Ok(name) => MethodId::parse(&name)
            .unwrap_or_else(|| panic!("RODE_STIFF_METHOD={name} is not a registered method")),
        Err(_) => MethodId::TRBDF2,
    }
}

/// Full bitwise equality of two solutions (NaN-safe via bit comparison).
fn assert_bitwise(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.status, b.status, "{label}: status");
    assert_eq!(a.stats, b.stats, "{label}: stats");
    let (fa, fb) = (a.ys_flat(), b.ys_flat());
    assert_eq!(fa.len(), fb.len(), "{label}: ys length");
    for (idx, (x, y)) in fa.iter().zip(fb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: ys[{idx}] {x} vs {y}");
    }
    assert_eq!(a.trace, b.trace, "{label}: trace");
}

/// VdP μ ∈ {10, 100, 1000, 5000} all reach Success under the implicit
/// method under test, and
/// the loose-tolerance solution agrees with a tight-tolerance
/// self-reference — the accuracy check that the Newton/Jacobian-reuse
/// machinery converges to the right trajectory, not just *a* trajectory.
#[test]
fn vdp_mu_sweep_solves_with_implicit() {
    for &mu in &[10.0, 100.0, 1000.0, 5000.0] {
        let sys = VdP::new(vec![mu]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        // The span sits on the slow branch of the relaxation oscillation
        // (see `vdp_stiff_span`), so the final-state comparison below is
        // well-conditioned.
        let grid = TimeGrid::linspace_shared(1, 0.0, vdp_stiff_span(mu), 9);
        let loose = SolveOptions::new(stiff_method())
            .with_tols(1e-6, 1e-4)
            .with_max_steps(1_000_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &loose);
        assert_eq!(sol.status[0], Status::Success, "mu={mu}: {:?}", sol.status[0]);
        // The implicit machinery really ran: Jacobians were built, LUs
        // factored, and Newton evaluations accrued beyond the batched
        // stage calls.
        let st = &sol.stats[0];
        assert!(st.n_jac_evals > 0, "mu={mu}: no Jacobian builds");
        assert!(st.n_lu_factor >= st.n_jac_evals, "mu={mu}: LU count");
        assert!(st.n_f_evals > 2 * st.n_steps, "mu={mu}: f-eval accounting");

        let tight = SolveOptions::new(stiff_method())
            .with_tols(1e-9, 1e-7)
            .with_max_steps(2_000_000);
        let reference = solve_ivp_parallel(&sys, &y0, &grid, &tight);
        assert_eq!(reference.status[0], Status::Success, "mu={mu} (tight)");
        for d in 0..2 {
            let (got, want) = (sol.y_final(0)[d], reference.y_final(0)[d]);
            assert!(
                (got - want).abs() < 5e-2 * (1.0 + want.abs()),
                "mu={mu} d={d}: {got} vs tight reference {want}"
            );
        }
    }
}

/// The Robertson kinetics problem (the classic stiff benchmark) solves
/// to Success with the implicit method and its analytic Jacobian,
/// conserves mass at every dense-output point, and agrees with a
/// tight-tolerance self-reference.
#[test]
fn robertson_solves_with_implicit() {
    let sys = Robertson::new(1);
    let y0 = BatchVec::from_rows(&[Robertson::y0().to_vec()]);
    let grid = TimeGrid::linspace_shared(1, 0.0, 100.0, 11);
    let opts = SolveOptions::new(stiff_method())
        .with_tols(1e-8, 1e-5)
        .with_max_steps(1_000_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert_eq!(sol.status[0], Status::Success, "{:?}", sol.status[0]);
    for e in 0..11 {
        let y = sol.y(0, e);
        let mass: f64 = y.iter().sum();
        assert!((mass - 1.0).abs() < 1e-5, "e={e}: mass {mass}");
        assert!(y[1].abs() < 1e-3, "e={e}: y2 = {} left the QSS regime", y[1]);
    }

    let tight = SolveOptions::new(stiff_method())
        .with_tols(1e-10, 1e-8)
        .with_max_steps(2_000_000);
    let reference = solve_ivp_parallel(&sys, &y0, &grid, &tight);
    assert_eq!(reference.status[0], Status::Success);
    for d in 0..3 {
        let (got, want) = (sol.y_final(0)[d], reference.y_final(0)[d]);
        assert!(
            (got - want).abs() < 1e-3 * (1.0 + want.abs()),
            "d={d}: {got} vs {want}"
        );
    }
}

/// Pin the wall the tentpole removes: explicit Dopri5 at μ = 1000 with
/// the minimum step pinned just above the method's stability ceiling
/// (|hλ| ≲ 3.3 with λ ≈ −3μ ⇒ h_stable ≈ 1.1·10⁻³ < min_dt = 4·10⁻³)
/// must ride its rejections into `DtUnderflow` — while TR-BDF2 under
/// the *same* options steps straight through.
#[test]
fn explicit_dopri5_still_underflows_at_mu_1000() {
    let sys = VdP::new(vec![1000.0]);
    let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
    let grid = TimeGrid::linspace_shared(1, 0.0, 400.0, 5);
    let mut opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-4)
        .with_dt0(0.01)
        .with_max_steps(500_000);
    opts.min_dt_rel = 1e-5; // min_dt = 400·1e-5 = 4e-3, above the stability ceiling
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert_eq!(
        sol.status[0],
        Status::DtUnderflow,
        "explicit dopri5 should hit the stiffness wall, got {:?}",
        sol.status[0]
    );

    // Same options, implicit method: the wall is gone.
    let mut iopts = opts.clone();
    iopts.method = MethodId::TRBDF2;
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &iopts);
    assert_eq!(sol.status[0], Status::Success, "{:?}", sol.status[0]);
}

/// The acceptance batch: 256 rows, one μ=1000 straggler among easy
/// μ=0.5 oscillators, solved by the **parallel** loop with the implicit
/// method under test —
/// Success everywhere, and bitwise-identical (trajectories, traces and
/// every `Stats` counter including `n_f_evals`/`n_jac_evals`/
/// `n_lu_factor`) across pool kind × threads × steal-chunk × layout ×
/// compaction.
#[test]
fn implicit_parallel_batch256_bitwise_across_pools_layouts_compaction() {
    let batch = 256;
    let mut mus = vec![0.5; batch];
    mus[0] = 1000.0;
    let sys = VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], batch);
    let grid = TimeGrid::linspace_shared(batch, 0.0, 40.0, 6);
    let base = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_max_steps(1_000_000)
        .with_trace();
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert!(serial.all_success(), "serial: {:?}", &serial.status[..4]);
    // The stiff straggler did real Newton work; the easy rows did their
    // own, smaller share (per-row accounting).
    assert!(serial.stats[0].n_jac_evals > 0);
    assert!(serial.stats[0].n_steps > serial.stats[1].n_steps);

    for layout in [Layout::RowMajor, Layout::DimMajor] {
        for compact in [0.0, 0.5] {
            for (kind, threads, chunk) in [
                (PoolKind::Scoped, 4, 0),
                (PoolKind::Persistent, 4, 0),
                (PoolKind::Persistent, 7, 5),
            ] {
                let opts = base
                    .clone()
                    .with_layout(layout)
                    .with_compaction(compact)
                    .with_threads(threads)
                    .with_pool(kind)
                    .with_steal_chunk(chunk);
                let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(
                    &serial,
                    &got,
                    &format!(
                        "parallel {} {} compact={compact} threads={threads} chunk={chunk}",
                        kind.name(),
                        layout.name()
                    ),
                );
            }
        }
    }
}

/// The same acceptance batch through the **joint** loop (shared
/// controller): Success, and bitwise-identical across pool kinds,
/// thread counts, steal-chunks and layouts.
#[test]
fn implicit_joint_batch256_bitwise_across_pools_and_layouts() {
    let batch = 256;
    let mut mus = vec![0.5; batch];
    mus[0] = 1000.0;
    let sys = VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], batch);
    let grid = TimeGrid::linspace_shared(batch, 0.0, 10.0, 5);
    let base = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_max_steps(1_000_000);
    let serial = solve_ivp_joint(&sys, &y0, &grid, &base);
    assert!(serial.all_success(), "serial joint: {:?}", &serial.status[..4]);

    for layout in [Layout::RowMajor, Layout::DimMajor] {
        for (kind, threads, chunk) in [
            (PoolKind::Scoped, 4, 0),
            (PoolKind::Persistent, 4, 0),
            (PoolKind::Persistent, 3, 8),
        ] {
            let opts = base
                .clone()
                .with_layout(layout)
                .with_threads(threads)
                .with_pool(kind)
                .with_steal_chunk(chunk);
            let got = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(
                &serial,
                &got,
                &format!("joint {} {} threads={threads} chunk={chunk}", kind.name(), layout.name()),
            );
        }
    }
}

/// The reaction–diffusion workload (Fisher–KPP method of lines,
/// tridiagonal Jacobian → banded Newton) reaches `Status::Success` with
/// the implicit method under test, does real Newton work, keeps the
/// state inside the PDE's invariant region `[0, 1]`, and agrees with a
/// tight-tolerance self-reference — the accuracy bar for the banded
/// factorization, not just a "didn't crash" check.
#[test]
fn reaction_diffusion_solves_with_implicit_and_matches_tight_reference() {
    let (batch, dim) = (4, 64);
    let sys = ReactionDiffusion::sweep(batch, dim);
    let y0 = BatchVec::from_rows(&sys.front_y0(batch));
    let grid = TimeGrid::linspace_shared(batch, 0.0, 0.5, 5);
    let opts = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_max_steps(200_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success(), "{:?}", sol.status);
    for i in 0..batch {
        assert!(sol.stats[i].n_jac_evals > 0, "row {i}: no Jacobian builds");
        assert!(sol.stats[i].n_lu_factor >= sol.stats[i].n_jac_evals, "row {i}: LU count");
        for e in 0..5 {
            for &u in sol.y(i, e) {
                assert!(
                    (-1e-3..=1.0 + 1e-3).contains(&u),
                    "row {i} eval {e}: u = {u} left the invariant region [0, 1]"
                );
            }
        }
    }

    let tight = SolveOptions::new(stiff_method())
        .with_tols(1e-9, 1e-7)
        .with_max_steps(2_000_000);
    let reference = solve_ivp_parallel(&sys, &y0, &grid, &tight);
    assert!(reference.all_success(), "tight: {:?}", reference.status);
    for i in 0..batch {
        for d in 0..dim {
            let (got, want) = (sol.y_final(i)[d], reference.y_final(i)[d]);
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "row {i} d={d}: {got} vs tight reference {want}"
            );
        }
    }
}

/// The banded factorization is a cost win, not a different computation:
/// forcing the dense path on the same reaction–diffusion problem (via
/// the `SolveOptions::jac_structure` override) must reproduce the banded
/// solve **bitwise** — trajectories and every `Stats` counter (both arms
/// use the analytic Jacobian hooks, so even `n_f_evals` agrees).
#[test]
fn reaction_diffusion_banded_matches_forced_dense_bitwise() {
    let (batch, dim) = (3, 48);
    let sys = ReactionDiffusion::sweep(batch, dim);
    let y0 = BatchVec::from_rows(&sys.front_y0(batch));
    let grid = TimeGrid::linspace_shared(batch, 0.0, 0.4, 4);
    let base = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_max_steps(200_000)
        .with_trace();
    let banded = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert!(banded.all_success(), "banded: {:?}", banded.status);
    let dense = solve_ivp_parallel(
        &sys,
        &y0,
        &grid,
        &base.clone().with_jac_structure(JacStructure::Dense),
    );
    assert_bitwise(&banded, &dense, "banded vs forced-dense");
}

/// The banded-path acceptance matrix: a mixed-stiffness
/// reaction–diffusion batch through the **parallel** loop must be
/// bitwise-identical across pool kind × threads × steal-chunk × layout ×
/// compaction — the same determinism contract the dense implicit path
/// holds, now with the banded Newton scratch moving under compaction and
/// splitting across shard workers.
#[test]
fn reaction_diffusion_parallel_bitwise_across_pools_layouts_compaction() {
    let (batch, dim) = (32, 64);
    let sys = ReactionDiffusion::sweep(batch, dim);
    let y0 = BatchVec::from_rows(&sys.front_y0(batch));
    let grid = TimeGrid::linspace_shared(batch, 0.0, 0.25, 4);
    let base = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_max_steps(200_000)
        .with_trace();
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert!(serial.all_success(), "serial: {:?}", &serial.status[..4]);

    for layout in [Layout::RowMajor, Layout::DimMajor] {
        for compact in [0.0, 0.5] {
            for (kind, threads, chunk) in [
                (PoolKind::Scoped, 4, 0),
                (PoolKind::Persistent, 4, 0),
                (PoolKind::Persistent, 7, 5),
            ] {
                let opts = base
                    .clone()
                    .with_layout(layout)
                    .with_compaction(compact)
                    .with_threads(threads)
                    .with_pool(kind)
                    .with_steal_chunk(chunk);
                let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
                assert_bitwise(
                    &serial,
                    &got,
                    &format!(
                        "rd parallel {} {} compact={compact} threads={threads} chunk={chunk}",
                        kind.name(),
                        layout.name()
                    ),
                );
            }
        }
    }
}

/// The same reaction–diffusion batch through the **joint** loop: the
/// banded Newton scratch splits across the pooled joint executors'
/// workspace views, bitwise-identically across pool kinds, thread
/// counts, steal-chunks and layouts.
#[test]
fn reaction_diffusion_joint_bitwise_across_pools_and_layouts() {
    let (batch, dim) = (32, 64);
    let sys = ReactionDiffusion::sweep(batch, dim);
    let y0 = BatchVec::from_rows(&sys.front_y0(batch));
    let grid = TimeGrid::linspace_shared(batch, 0.0, 0.2, 3);
    let base = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_max_steps(200_000);
    let serial = solve_ivp_joint(&sys, &y0, &grid, &base);
    assert!(serial.all_success(), "serial joint: {:?}", &serial.status[..4]);

    for layout in [Layout::RowMajor, Layout::DimMajor] {
        for (kind, threads, chunk) in [
            (PoolKind::Scoped, 4, 0),
            (PoolKind::Persistent, 4, 0),
            (PoolKind::Persistent, 3, 8),
        ] {
            let opts = base
                .clone()
                .with_layout(layout)
                .with_threads(threads)
                .with_pool(kind)
                .with_steal_chunk(chunk);
            let got = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(
                &serial,
                &got,
                &format!(
                    "rd joint {} {} threads={threads} chunk={chunk}",
                    kind.name(),
                    layout.name()
                ),
            );
        }
    }
}

/// A fixed-step implicit solve whose Newton iteration cannot converge
/// must fail loudly with the dedicated `Status::NewtonDiverged` — not
/// silently shrink the "fixed" step, and not misreport `DtUnderflow`.
/// The probe is `y' = y²` from y0 = 2 at h = 1: the trapezoidal stage
/// equation `z = rhs + h·d·z²` has negative discriminant (no real
/// solution), so divergence is guaranteed, fresh Jacobian or not.
#[test]
fn fixed_step_newton_divergence_is_reported() {
    struct Quadratic;
    impl OdeSystem for Quadratic {
        fn dim(&self) -> usize {
            1
        }
        fn f_inst(&self, _inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
            dy[0] = y[0] * y[0];
        }
    }
    let sys = Quadratic;
    let y0 = BatchVec::from_rows(&[vec![2.0]]);
    let grid = TimeGrid::linspace_shared(1, 0.0, 2.0, 3);
    let opts = SolveOptions::new(MethodId::TRBDF2).with_fixed_dt(1.0).with_max_steps(100);
    // Parallel loop: the row fails outright.
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert_eq!(sol.status[0], Status::NewtonDiverged, "{:?}", sol.status[0]);
    // Joint loop: the shared fixed step fails the whole batch the same
    // way (the batch here is one row; the status must still be the
    // dedicated one, not DtUnderflow or MaxStepsReached).
    let sol = solve_ivp_joint(&sys, &y0, &grid, &opts);
    assert_eq!(sol.status[0], Status::NewtonDiverged, "{:?}", sol.status[0]);
}

/// Newton divergence feeds the rejection path, not a death spiral: a
/// solve that starts with an absurdly large dt0 must recover (reject,
/// shrink, refresh the Jacobian) and still finish with Success.
#[test]
fn newton_divergence_recovers_through_rejection() {
    let sys = VdP::new(vec![100.0]);
    let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
    let grid = TimeGrid::linspace_shared(1, 0.0, 40.0, 5);
    let opts = SolveOptions::new(stiff_method())
        .with_tols(1e-6, 1e-4)
        .with_dt0(40.0) // the whole span in one step — Newton will diverge
        .with_max_steps(200_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert_eq!(sol.status[0], Status::Success, "{:?}", sol.status[0]);
    // Divergence shows up as rejected attempts, not as an aborted solve.
    assert!(sol.stats[0].n_steps > sol.stats[0].n_accepted);
}
