//! Golden test: the Rust tableaus and the Python tableaus are the same
//! numbers. `make artifacts` dumps `artifacts/tableaus.json` from
//! `python/compile/tableaus.py`; this test compares every coefficient.

use rode::runtime::json::Json;
use rode::solver::Method;

fn load() -> Option<Json> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tableaus.json");
    if !p.exists() {
        eprintln!("skipping: tableaus.json not built (run `make artifacts`)");
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap())
}

fn check_method(j: &Json, m: Method) {
    let tab = m.tableau();
    let jt = j.get(tab.name).unwrap_or_else(|| panic!("{} missing from JSON", tab.name));
    assert_eq!(jt.get("stages").unwrap().as_usize(), Some(tab.stages), "{}", tab.name);
    assert_eq!(jt.get("order").unwrap().as_usize(), Some(tab.order), "{}", tab.name);
    assert_eq!(
        jt.get("err_order").unwrap().as_usize(),
        Some(tab.err_order),
        "{}",
        tab.name
    );
    let cmp = |key: &str, rust: &[f64]| {
        let py: Vec<f64> = jt
            .get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(py.len(), rust.len(), "{}.{key} length", tab.name);
        for (i, (p, r)) in py.iter().zip(rust).enumerate() {
            assert!(
                (p - r).abs() <= 1e-15 * (1.0 + r.abs()),
                "{}.{key}[{i}]: python {p} vs rust {r}",
                tab.name
            );
        }
    };
    cmp("a", tab.a);
    cmp("b", tab.b);
    cmp("b_err", tab.b_err);
    cmp("c", tab.c);
}

#[test]
fn python_and_rust_tableaus_agree() {
    let Some(j) = load() else { return };
    for m in [Method::Dopri5, Method::Tsit5, Method::Bosh3] {
        check_method(&j, m);
    }
}
