//! Golden test: the Rust tableaus and the Python tableaus are the same
//! numbers. `make artifacts` dumps `artifacts/tableaus.json` from
//! `python/compile/tableaus.py`; this test compares every coefficient.
//!
//! Also home to the registry-wide structure invariants: every method the
//! registry will route to — built-in or runtime-registered — must satisfy
//! the same shape and consistency checks, enforced over `MethodId::all()`.

use rode::runtime::json::Json;
use rode::solver::MethodId;

fn load() -> Option<Json> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tableaus.json");
    if !p.exists() {
        eprintln!("skipping: tableaus.json not built (run `make artifacts`)");
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap())
}

fn check_method(j: &Json, m: MethodId) {
    let tab = m.tableau();
    let jt = j.get(tab.name).unwrap_or_else(|| panic!("{} missing from JSON", tab.name));
    assert_eq!(jt.get("stages").unwrap().as_usize(), Some(tab.stages), "{}", tab.name);
    assert_eq!(jt.get("order").unwrap().as_usize(), Some(tab.order), "{}", tab.name);
    assert_eq!(
        jt.get("err_order").unwrap().as_usize(),
        Some(tab.err_order),
        "{}",
        tab.name
    );
    let cmp = |key: &str, rust: &[f64]| {
        let py: Vec<f64> = jt
            .get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(py.len(), rust.len(), "{}.{key} length", tab.name);
        for (i, (p, r)) in py.iter().zip(rust).enumerate() {
            assert!(
                (p - r).abs() <= 1e-15 * (1.0 + r.abs()),
                "{}.{key}[{i}]: python {p} vs rust {r}",
                tab.name
            );
        }
    };
    cmp("a", tab.a);
    cmp("b", tab.b);
    cmp("b_err", tab.b_err);
    cmp("c", tab.c);
}

#[test]
fn python_and_rust_tableaus_agree() {
    let Some(j) = load() else { return };
    for m in [MethodId::DOPRI5, MethodId::TSIT5, MethodId::BOSH3] {
        check_method(&j, m);
    }
}

/// Structure invariants every registered method must satisfy. Runs over
/// the full registry snapshot, so a runtime-registered method picked up by
/// an earlier test in this binary is checked too — the registry has one
/// quality bar, not one for built-ins and one for everything else.
#[test]
fn every_registered_method_has_a_consistent_tableau() {
    for m in MethodId::all() {
        let t = m.tableau();
        let name = t.name;
        assert_eq!(m.name(), name, "registry name mismatch");
        assert!(t.stages >= 1, "{name}: no stages");
        // Shape: strictly-lower-triangular a, per-stage b/c, diag either
        // absent (explicit) or one entry per stage (ESDIRK).
        assert_eq!(t.a.len(), t.stages * (t.stages - 1) / 2, "{name}: a shape");
        assert_eq!(t.b.len(), t.stages, "{name}: b shape");
        assert_eq!(t.c.len(), t.stages, "{name}: c shape");
        assert!(t.diag.is_empty() || t.diag.len() == t.stages, "{name}: diag shape");
        assert_eq!(m.is_implicit(), !t.diag.is_empty(), "{name}: implicit flag");
        // Quadrature consistency: Σb = 1; the embedded difference sums to
        // zero (both weight vectors integrate constants exactly).
        let sb: f64 = t.b.iter().sum();
        assert!((sb - 1.0).abs() < 1e-9, "{name}: Σb = {sb}");
        if !t.b_err.is_empty() {
            assert_eq!(t.b_err.len(), t.stages, "{name}: b_err shape");
            let se: f64 = t.b_err.iter().sum();
            assert!(se.abs() < 1e-9, "{name}: Σb_err = {se}");
            assert!(t.err_order < t.order, "{name}: embedded order not lower");
        }
        // Row-sum consistency: c[i] = Σ_j a[i][j] (+ diag[i] for ESDIRK).
        assert_eq!(t.c[0], 0.0, "{name}: c[0]");
        let mut at = 0;
        for i in 1..t.stages {
            let row: f64 = t.a[at..at + i].iter().sum();
            at += i;
            let d = if t.diag.is_empty() { 0.0 } else { t.diag[i] };
            assert!((row + d - t.c[i]).abs() < 1e-9, "{name}: row {i} sum vs c");
        }
        // The compiled form agrees with the data and is slot-cached.
        let k = m.compiled();
        assert_eq!(k.is_implicit(), m.is_implicit(), "{name}: compiled flag");
        assert!(std::ptr::eq(k, m.compiled()), "{name}: compiled not slot-cached");
    }
    // The registry starts from the full built-in set.
    assert!(MethodId::all().len() >= MethodId::BUILTINS.len());
}
