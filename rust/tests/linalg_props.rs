//! Seeded-randomized property suite for the banded LU path
//! (`solver/linalg.rs`), with the dense LU as the oracle.
//!
//! The banded factorization is a *storage* optimization, not a different
//! algorithm: on any matrix whose nonzeros fit the declared band it must
//! perform the same pivot choices and (up to structural zeros) the same
//! arithmetic as the dense code. These tests drive that claim over
//! hundreds of random band patterns, the degenerate bandwidths
//! (diagonal-only, full band ≡ dense bitwise), singular inputs, and
//! adversarial near-singular matrices that force pivoting.

use rode::nn::Rng64;
use rode::solver::linalg::{
    banded_lu_factor, banded_lu_solve, banded_width, lu_factor, lu_solve, BandedMatrix,
};

/// A random dense row-major matrix whose nonzeros lie inside the
/// `(kl, ku)` band; entries uniform in `[-1, 1)`.
fn random_banded_dense(rng: &mut Rng64, n: usize, kl: usize, ku: usize) -> Vec<f64> {
    let mut a = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            if i <= j + kl && j <= i + ku {
                a[i * n + j] = rng.range(-1.0, 1.0);
            }
        }
    }
    a
}

/// `‖A x − b‖∞` for a dense row-major `A`.
fn residual_inf(a: &[f64], n: usize, x: &[f64], b: &[f64]) -> f64 {
    (0..n)
        .map(|i| {
            let ax: f64 = (0..n).map(|j| a[i * n + j] * x[j]).sum();
            (ax - b[i]).abs()
        })
        .fold(0.0, f64::max)
}

/// Factor + solve `a` (dense row-major) through both paths and compare.
/// Returns `true` when both declared the matrix singular.
fn check_banded_vs_dense(a: &[f64], n: usize, kl: usize, ku: usize, b: &[f64]) -> bool {
    let mut dense = a.to_vec();
    let mut piv_d = vec![0usize; n];
    let ok_d = lu_factor(&mut dense, &mut piv_d, n);

    let mut banded = BandedMatrix::from_dense(a, n, kl, ku);
    let mut piv_b = vec![0usize; n];
    let ok_b = banded.factor(&mut piv_b);

    assert_eq!(
        ok_d, ok_b,
        "singularity verdicts disagree (dense {ok_d}, banded {ok_b}) for n={n} kl={kl} ku={ku}"
    );
    if !ok_d {
        return true;
    }

    let mut x_d = b.to_vec();
    lu_solve(&dense, &piv_d, n, &mut x_d);
    let mut x_b = b.to_vec();
    banded.solve(&piv_b, &mut x_b);

    // Solution scale for the relative tolerance: random unit-scale
    // matrices can still be badly conditioned, so normalize by ‖x‖∞.
    let scale = x_d.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for i in 0..n {
        assert!(
            (x_d[i] - x_b[i]).abs() <= 1e-12 * scale,
            "x[{i}] dense {} vs banded {} (n={n} kl={kl} ku={ku}, scale {scale})",
            x_d[i],
            x_b[i]
        );
    }
    false
}

#[test]
fn random_band_patterns_agree_with_dense_oracle() {
    let mut singular = 0u32;
    for seed in 0..250u64 {
        let mut rng = Rng64::new(seed);
        let n = 1 + rng.below(32);
        let kl = rng.below(5).min(n - 1);
        let ku = rng.below(5).min(n - 1);
        let a = random_banded_dense(&mut rng, n, kl, ku);
        let b: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        if check_banded_vs_dense(&a, n, kl, ku, &b) {
            singular += 1;
        }
    }
    // Random real matrices are almost surely nonsingular — if a
    // noticeable fraction tripped the singularity path, the comparison
    // wasn't exercising the solver at all.
    assert!(singular < 25, "{singular}/250 random matrices reported singular");
}

#[test]
fn diagonal_only_band_is_elementwise_division() {
    for seed in 0..50u64 {
        let mut rng = Rng64::new(1000 + seed);
        let n = 1 + rng.below(16);
        // Diagonal entries bounded away from zero.
        let d: Vec<f64> = (0..n)
            .map(|_| {
                let v = rng.range(0.1, 2.0);
                if rng.below(2) == 0 {
                    -v
                } else {
                    v
                }
            })
            .collect();
        let b: Vec<f64> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();

        let mut ab: Vec<f64> = d.clone(); // width = 1 for kl = ku = 0
        let mut piv = vec![0usize; n];
        assert!(banded_lu_factor(&mut ab, &mut piv, n, 0, 0));
        let mut x = b.clone();
        banded_lu_solve(&ab, &piv, n, 0, 0, &mut x);
        for i in 0..n {
            assert_eq!(x[i].to_bits(), (b[i] / d[i]).to_bits(), "row {i}");
            assert_eq!(piv[i], i, "diagonal-only must never pivot");
        }
    }
}

#[test]
fn full_band_reproduces_dense_bitwise() {
    // With kl = ku = n − 1 the banded storage holds every entry, the
    // pivot search scans the same candidates, and the elimination
    // performs the identical operation sequence — so factor and solve
    // must match the dense path *bitwise*, pivots included.
    for seed in 0..60u64 {
        let mut rng = Rng64::new(2000 + seed);
        let n = 1 + rng.below(12);
        let (kl, ku) = (n - 1, n - 1);
        let mut a = vec![0.0; n * n];
        for v in a.iter_mut() {
            *v = rng.normal();
        }
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        let mut dense = a.clone();
        let mut piv_d = vec![0usize; n];
        assert!(lu_factor(&mut dense, &mut piv_d, n));
        let mut x_d = b.clone();
        lu_solve(&dense, &piv_d, n, &mut x_d);

        let mut banded = BandedMatrix::from_dense(&a, n, kl, ku);
        let mut piv_b = vec![0usize; n];
        assert!(banded.factor(&mut piv_b));
        let mut x_b = b.clone();
        banded.solve(&piv_b, &mut x_b);

        assert_eq!(piv_d, piv_b, "pivot sequences diverged (seed {seed}, n={n})");
        for i in 0..n {
            assert_eq!(
                x_d[i].to_bits(),
                x_b[i].to_bits(),
                "x[{i}] dense {} vs banded {} (seed {seed}, n={n})",
                x_d[i],
                x_b[i]
            );
        }
    }
}

#[test]
fn singularity_detection_agrees_with_dense() {
    for seed in 0..100u64 {
        let mut rng = Rng64::new(3000 + seed);
        let n = 2 + rng.below(20);
        let kl = rng.below(4).min(n - 1);
        let ku = rng.below(4).min(n - 1);
        let mut a = random_banded_dense(&mut rng, n, kl, ku);
        // Zero out one column: exactly singular, and elimination keeps
        // the column exactly zero, so both paths must report it.
        let dead = rng.below(n);
        for i in 0..n {
            a[i * n + dead] = 0.0;
        }
        let b = vec![1.0; n];
        assert!(
            check_banded_vs_dense(&a, n, kl, ku, &b),
            "zeroed column {dead} not reported singular (seed {seed}, n={n})"
        );
    }
}

#[test]
fn near_singular_matrices_force_pivoting_and_stay_accurate() {
    // Tridiagonal matrices with an ~1e-14 diagonal and O(1)
    // off-diagonals: without row pivoting the elimination divides by the
    // tiny pivot and the solution loses every significant digit; with
    // partial pivoting the residual stays at roundoff scale.
    for seed in 0..50u64 {
        let mut rng = Rng64::new(4000 + seed);
        let n = 3 + rng.below(24);
        let (kl, ku) = (1usize, 1usize);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1e-14 * rng.range(0.5, 2.0);
            if i > 0 {
                a[i * n + (i - 1)] = rng.range(0.5, 2.0);
            }
            if i + 1 < n {
                a[i * n + (i + 1)] = rng.range(0.5, 2.0);
            }
        }
        let b: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();

        let mut banded = BandedMatrix::from_dense(&a, n, kl, ku);
        let mut piv = vec![0usize; n];
        assert!(banded.factor(&mut piv));
        assert!(
            piv.iter().enumerate().any(|(k, &p)| p != k),
            "tiny-diagonal tridiagonal must pivot (seed {seed}, n={n})"
        );
        let mut x = b.clone();
        banded.solve(&piv, &mut x);
        let scale = x.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let res = residual_inf(&a, n, &x, &b);
        assert!(
            res <= 1e-10 * scale,
            "residual {res} too large for scale {scale} (seed {seed}, n={n})"
        );

        // And the dense oracle agrees on the solution.
        let b2 = b.clone();
        check_banded_vs_dense(&a, n, kl, ku, &b2);
    }
}

#[test]
fn pivot_fill_headroom_is_what_gets_factored() {
    // The factored band is wider than the assembly band (kl extra rows
    // of fill per column). Assemble through `BandedMatrix` (which owns
    // the width bookkeeping) and cross-check one hand-built matrix
    // against the raw free functions to pin the layout contract.
    let n = 4;
    let (kl, ku) = (1usize, 1usize);
    let a = [
        0.0, 2.0, 0.0, 0.0, //
        1.0, 0.0, 3.0, 0.0, //
        0.0, 4.0, 1.0, 5.0, //
        0.0, 0.0, 2.0, 6.0, //
    ];
    let w = banded_width(kl, ku);
    let mut ab = vec![0.0; n * w];
    for i in 0..n {
        for j in 0..n {
            if a[i * n + j] != 0.0 {
                ab[j * w + (kl + ku + i) - j] = a[i * n + j];
            }
        }
    }
    let mut piv = vec![0usize; n];
    assert!(banded_lu_factor(&mut ab, &mut piv, n, kl, ku));
    let b = [1.0, -2.0, 0.5, 3.0];
    let mut x = b;
    banded_lu_solve(&ab, &piv, n, kl, ku, &mut x);
    let res = residual_inf(&a, n, &x, &b);
    assert!(res < 1e-12, "residual {res}");

    let mut via_struct = BandedMatrix::from_dense(&a, n, kl, ku);
    let mut piv2 = vec![0usize; n];
    assert!(via_struct.factor(&mut piv2));
    assert_eq!(piv, piv2);
    let mut x2 = b;
    via_struct.solve(&piv2, &mut x2);
    for i in 0..n {
        assert_eq!(x[i].to_bits(), x2[i].to_bits());
    }
}
