//! Zero-allocation steady state, enforced.
//!
//! A counting global allocator wraps `System`; the test solves the same
//! problem over a short and a long time span (same batch, same number of
//! eval points, same `max_steps`, several times more solver steps) and
//! asserts the **allocation counts are identical**. Setup cost (solution
//! buffers, workspace, ledger reservation) is the same for both, so any
//! difference can only come from per-step allocations — which the
//! active-set loop, the stage kernel (`rk_attempt`/`rk_attempt_active`)
//! and the joint loop must not perform.
//!
//! This file holds exactly one `#[test]` so no concurrent test can touch
//! the global counter mid-measurement.

use rode::coordinator::{
    Coordinator, NativeEngine, ProblemSpec, RetryPolicy, ServiceConfig, SolveRequest,
};
use rode::prelude::*;
use rode::problems::{ExponentialDecay, ReactionDiffusion, VdP};
use rode::solver::{backsolve_adjoint_parallel, AdjointOptions};
use rode::tensor::BatchVec;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during(f: impl FnOnce()) -> usize {
    let before = ALLOCS.load(Ordering::SeqCst);
    f();
    ALLOCS.load(Ordering::SeqCst) - before
}

/// Mixed-stiffness batch so rows finish at different times and compaction
/// fires mid-solve.
fn workload(t1: f64) -> (VdP, BatchVec, TimeGrid) {
    let mus = vec![0.5, 4.0, 1.0, 8.0, 2.0, 0.8, 6.0, 1.5];
    let b = mus.len();
    let sys = VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, t1, 6);
    (sys, y0, grid)
}

fn parallel_steps(t1: f64, opts: &SolveOptions) -> (usize, u64) {
    let (sys, y0, grid) = workload(t1);
    let mut steps = 0;
    let n = allocs_during(|| {
        let sol = solve_ivp_parallel(&sys, &y0, &grid, opts);
        assert!(sol.all_success());
        steps = sol.max_steps();
        std::hint::black_box(sol.ys_flat()[0]);
    });
    (n, steps)
}

/// The banded-Newton workload: a mixed-diffusion Fisher–KPP batch whose
/// tridiagonal Jacobian routes the implicit solver through the banded
/// factorization (`t1` is pre-scaled by the caller — the PDE's time
/// scale is shorter than Van der Pol's).
fn rd_steps(t1: f64, opts: &SolveOptions) -> (usize, u64) {
    let sys = ReactionDiffusion::sweep(6, 32);
    let y0 = BatchVec::from_rows(&sys.front_y0(6));
    let grid = TimeGrid::linspace_shared(6, 0.0, t1, 6);
    let mut steps = 0;
    let n = allocs_during(|| {
        let sol = solve_ivp_parallel(&sys, &y0, &grid, opts);
        assert!(sol.all_success());
        steps = sol.max_steps();
        std::hint::black_box(sol.ys_flat()[0]);
    });
    (n, steps)
}

fn joint_steps(t1: f64, opts: &SolveOptions) -> (usize, u64) {
    let (sys, y0, grid) = workload(t1);
    let mut steps = 0;
    let n = allocs_during(|| {
        let sol = solve_ivp_joint(&sys, &y0, &grid, opts);
        assert!(sol.all_success());
        steps = sol.max_steps();
        std::hint::black_box(sol.ys_flat()[0]);
    });
    (n, steps)
}

/// One request through the full serving path (submit → bucket → dispatch
/// → response). The request-shaped costs — channel nodes, waiter entry,
/// batch rebuild, response buffers — are identical for both spans, so a
/// count difference can only come from per-step allocations leaking into
/// the service layer. The coordinator is spawned and warmed outside the
/// measured window; only the worker thread touches the allocator while
/// the window is open (the submitter blocks on `recv`).
fn service_steps(t1: f64) -> (usize, u64) {
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(20_000);
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue: 0,
            retry: RetryPolicy::disabled(),
            // One worker: the allocation window assumes exactly one worker
            // thread touches the allocator while it is open.
            workers: 1,
            ..ServiceConfig::default()
        },
        move || Box::new(NativeEngine::new(opts.clone())),
    );
    let req = || {
        SolveRequest::new(
            ProblemSpec::Vdp { mu: 2.0 },
            vec![2.0, 0.0],
            (0..6).map(|k| k as f64 * t1 / 5.0).collect(),
        )
    };
    let warm = coord.solve_blocking(req()).expect("worker must be alive");
    assert!(warm.is_success());
    let mut steps = 0;
    let n = allocs_during(|| {
        let resp = coord.solve_blocking(req()).expect("worker must be alive");
        assert!(resp.is_success());
        steps = resp.stats.n_steps;
        std::hint::black_box(resp.ys[0]);
    });
    (n, steps)
}

/// The backsolve adjoint's memory contract: the whole backward pass
/// (checkpoint re-solve plus per-segment augmented solves) performs a
/// span-independent number of allocations even as the forward and
/// backward step counts grow with the horizon — O(checkpoints) memory,
/// never O(steps). The forward solve for `y1` runs outside the window;
/// everything `backsolve_adjoint_parallel` does is inside it.
fn backsolve_steps(t1: f64) -> (usize, u64) {
    let lams = vec![0.15, 0.3, 0.5, 0.2, 0.45, 0.25, 0.4, 0.35];
    let b = lams.len();
    let sys = ExponentialDecay::new(lams, 2);
    let y0 = BatchVec::broadcast(&[2.0, -1.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, t1, 2);
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8).with_max_steps(20_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success());
    let mut y1 = BatchVec::zeros(b, 2);
    for i in 0..b {
        y1.row_mut(i).copy_from_slice(sol.y_final(i));
    }
    let dl = BatchVec::broadcast(&[1.0, 0.5], b);
    let t0s = vec![0.0; b];
    let t1s = vec![t1; b];
    let adj = AdjointOptions::new(opts).with_checkpoints(3);
    let mut steps = 0;
    let n = allocs_during(|| {
        let res = backsolve_adjoint_parallel(&sys, &y0, &y1, &dl, &t0s, &t1s, &adj);
        assert!(res.status.iter().all(|s| *s == Status::Success));
        steps = res.stats.iter().map(|s| s.n_steps).sum();
        std::hint::black_box(res.dl_dparams[0]);
    });
    (n, steps)
}

type Case = (&'static str, Box<dyn Fn(f64) -> (usize, u64)>);

/// Allocation counts must not scale with step count, for the parallel
/// active-set loop (with compaction enabled, both eval modes) and the
/// joint loop. Retried a few times to ride out test-harness noise on the
/// process-global counter; a genuine per-step allocation fails every
/// attempt.
#[test]
fn steady_state_allocates_nothing() {
    let cases: Vec<Case> = vec![
        (
            "parallel skip_inactive+compact",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::DOPRI5)
                    .with_tols(1e-6, 1e-6)
                    .with_max_steps(20_000)
                    .skip_inactive()
                    .with_compaction(0.5);
                parallel_steps(t1, &opts)
            }),
        ),
        (
            "parallel overhang evals",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::DOPRI5)
                    .with_tols(1e-6, 1e-6)
                    .with_max_steps(20_000);
                parallel_steps(t1, &opts)
            }),
        ),
        (
            "parallel non-FSAL",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::FEHLBERG45)
                    .with_tols(1e-6, 1e-6)
                    .with_max_steps(20_000)
                    .skip_inactive()
                    .with_compaction(1.0);
                parallel_steps(t1, &opts)
            }),
        ),
        (
            "joint",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::DOPRI5)
                    .with_tols(1e-6, 1e-6)
                    .with_max_steps(20_000);
                joint_steps(t1, &opts)
            }),
        ),
        // Implicit (TR-BDF2): the Newton scratch — Jacobian/LU blocks,
        // pivots, iterate rows, counters — must live entirely in the
        // workspace; neither the per-stage Newton loops nor the
        // finite-difference Jacobian builds may allocate per step.
        (
            "parallel implicit (trbdf2)",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::TRBDF2)
                    .with_tols(1e-6, 1e-5)
                    .with_max_steps(20_000)
                    .skip_inactive()
                    .with_compaction(0.5);
                parallel_steps(t1, &opts)
            }),
        ),
        (
            "joint implicit (trbdf2)",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::TRBDF2)
                    .with_tols(1e-6, 1e-5)
                    .with_max_steps(20_000);
                joint_steps(t1, &opts)
            }),
        ),
        // Banded implicit: the banded Jacobian/LU blocks, the colored
        // finite-difference builds and the banded factor/solve must all
        // live in the workspace — counts must not scale with step count,
        // at the problem's own bandwidth or under a wider override.
        (
            "parallel implicit banded (reaction-diffusion)",
            Box::new(|t1| {
                let opts = SolveOptions::new(MethodId::TRBDF2)
                    .with_tols(1e-6, 1e-5)
                    .with_max_steps(20_000)
                    .skip_inactive()
                    .with_compaction(0.5);
                rd_steps(t1 / 10.0, &opts)
            }),
        ),
        (
            "parallel implicit banded wide-band override",
            Box::new(|t1| {
                // A wider band than the problem declares: still a valid
                // cover of the tridiagonal nonzeros, but the analytic
                // band hook no longer applies, so this leg pins the
                // colored finite-difference build as allocation-free too.
                let opts = SolveOptions::new(MethodId::TRBDF2)
                    .with_tols(1e-6, 1e-5)
                    .with_max_steps(20_000)
                    .skip_inactive()
                    .with_compaction(0.5)
                    .with_jac_structure(JacStructure::Banded { lower: 3, upper: 3 });
                rd_steps(t1 / 10.0, &opts)
            }),
        ),
        // Backsolve adjoint: the training-facing O(1)-memory backward.
        ("backsolve adjoint (checkpointed)", Box::new(backsolve_steps)),
        // Full serving path: request-shaped allocations are fine, but the
        // count must not scale with solver steps.
        ("service path (coordinator + native engine)", Box::new(service_steps)),
    ];

    for (label, run) in &cases {
        // Warm up (first call may fault in allocator internals).
        run(3.0);
        let mut outcome = None;
        for _ in 0..3 {
            let (short_allocs, short_steps) = run(3.0);
            let (long_allocs, long_steps) = run(15.0);
            assert!(
                long_steps > short_steps,
                "{label}: long solve must take more steps ({long_steps} vs {short_steps})"
            );
            outcome = Some((short_allocs, long_allocs));
            if short_allocs == long_allocs {
                break;
            }
        }
        let (short_allocs, long_allocs) = outcome.unwrap();
        assert_eq!(
            short_allocs, long_allocs,
            "{label}: allocations scale with step count — the steady state is not allocation-free"
        );
    }
}
