//! The exec layer's contract: a sharded solve is **bitwise-identical** to
//! the serial reference path — `ys`, `Stats` (including the merged
//! `n_f_evals` accounting), `Status` and traces — for homogeneous and
//! heterogeneous batches, FSAL and non-FSAL methods, adaptive and fixed
//! step, and an oversubscribed pool.

use rode::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
use rode::prelude::*;
use rode::problems::VdP;
use rode::solver::Tolerances;
use rode::tensor::BatchVec;

/// Full bitwise equality of two solutions (NaN-safe via bit comparison).
fn assert_bitwise(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.status, b.status, "{label}: status");
    assert_eq!(a.stats, b.stats, "{label}: stats");
    let (fa, fb) = (a.ys_flat(), b.ys_flat());
    assert_eq!(fa.len(), fb.len(), "{label}: ys length");
    for (idx, (x, y)) in fa.iter().zip(fb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: ys[{idx}] {x} vs {y}");
    }
    assert_eq!(a.trace, b.trace, "{label}: trace");
}

fn het_vdp(batch: usize) -> (VdP, BatchVec, TimeGrid) {
    // Mixed stiffness: shard boundaries fall between very different
    // workloads, so shards finish after very different iteration counts.
    let mus: Vec<f64> = (0..batch)
        .map(|i| [0.5, 40.0, 2.0, 7.0, 0.8, 25.0, 4.0, 12.0][i % 8])
        .collect();
    let sys = VdP::new(mus);
    let y0 = BatchVec::from_rows(
        &(0..batch)
            .map(|i| vec![1.0 + 0.1 * (i % 5) as f64, 0.1 * (i % 3) as f64])
            .collect::<Vec<_>>(),
    );
    let grid = TimeGrid::linspace_shared(batch, 0.0, 5.0, 10);
    (sys, y0, grid)
}

/// The `heterogeneous_batch_isolated` scenario, sharded: stiff + easy
/// VdP instances split across 2..=batch workers must reproduce the
/// serial solve bitwise — on both pool kinds.
#[test]
fn heterogeneous_batch_sharded_bitwise() {
    let (sys, y0, grid) = het_vdp(6);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-7, 1e-7)
        .with_max_steps(200_000)
        .with_trace();
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert!(serial.all_success());
    for threads in [2, 3, 4, 6] {
        for kind in [PoolKind::Scoped, PoolKind::Persistent] {
            let opts = base.clone().with_threads(threads).with_pool(kind);
            let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            // The intended pool really ran (no silent serial fallback).
            assert_eq!(sharded.exec_stats.pool_kind, kind, "threads={threads}");
            assert_bitwise(&serial, &sharded, &format!("{kind:?} threads={threads}"));
        }
    }
}

/// The `batch_of_identical_problems_identical_answers` scenario, sharded.
#[test]
fn identical_problems_sharded_bitwise() {
    let b = 8;
    let sys = VdP::uniform(b, 2.0);
    let y0 = BatchVec::broadcast(&[1.0, 0.5], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, 5.0, 10);
    let base = SolveOptions::new(MethodId::TSIT5).with_tols(1e-6, 1e-6);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(4));
    assert!(sharded.all_success());
    assert_bitwise(&serial, &sharded, "identical-batch");
    // And the torchode invariants survive the merge.
    for i in 1..b {
        assert_eq!(sharded.stats[i], sharded.stats[0]);
        for e in 0..10 {
            assert_eq!(sharded.y(i, e), sharded.y(0, e));
        }
    }
}

/// Non-FSAL methods exercise the refresh entry of the call ledger: the
/// merged `n_f_evals` must still match the serial loop exactly even when
/// shards run for very different iteration counts.
#[test]
fn non_fsal_methods_sharded_bitwise() {
    // Mild heterogeneity: low-order methods (Heun) stay fast in debug
    // builds while shards still finish after different iteration counts.
    let sys = VdP::new(vec![0.5, 8.0, 2.0, 5.0, 0.8]);
    let y0 = BatchVec::from_rows(
        &(0..5).map(|i| vec![1.0 + 0.1 * i as f64, 0.0]).collect::<Vec<_>>(),
    );
    let grid = TimeGrid::linspace_shared(5, 0.0, 4.0, 9);
    for m in [MethodId::FEHLBERG45, MethodId::HEUN, MethodId::CASHKARP45] {
        let base = SolveOptions::new(m).with_tols(1e-6, 1e-6).with_max_steps(1_000_000);
        let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
        let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(3));
        assert_bitwise(&serial, &sharded, &format!("{m:?}"));
    }
}

/// Fixed-step methods (non-adaptive, non-FSAL) shard too.
#[test]
fn fixed_step_sharded_bitwise() {
    let (sys, y0, grid) = het_vdp(4);
    let base = SolveOptions::new(MethodId::RK4).with_fixed_dt(1e-3).with_max_steps(10_000);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(2));
    assert_bitwise(&serial, &sharded, "rk4-fixed");
}

/// An oversubscribed pool (threads > batch) degrades to one shard per
/// row and stays safe and bitwise-correct.
#[test]
fn oversubscribed_pool_is_safe() {
    let (sys, y0, grid) = het_vdp(3);
    let base = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(100_000);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(16));
    assert_bitwise(&serial, &sharded, "oversubscribed");
    // threads = 0 resolves to the core count; still bitwise.
    let auto = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(0));
    assert_bitwise(&serial, &auto, "auto-threads");
}

/// Failing instances merge faithfully: a max-steps-limited stiff row
/// reports the same status/stats/NaN pattern under sharding.
#[test]
fn failure_status_merges_bitwise() {
    let sys = VdP::new(vec![0.5, 1000.0]);
    let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0]]);
    let grid = TimeGrid::linspace_shared(2, 0.0, 50.0, 10);
    let base = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8).with_max_steps(60);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    assert_eq!(serial.status[1], Status::MaxStepsReached);
    let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(2));
    assert_bitwise(&serial, &sharded, "max-steps");
}

/// Per-instance tolerance vectors are sliced per shard and still produce
/// the serial result bitwise.
#[test]
fn per_instance_tolerances_shard_correctly() {
    let (sys, y0, grid) = het_vdp(6);
    let mut base = SolveOptions::new(MethodId::DOPRI5).with_max_steps(400_000);
    base.tols = Tolerances::per_instance(
        vec![1e-5, 1e-7, 1e-6, 1e-8, 1e-5, 1e-6],
        vec![1e-5, 1e-7, 1e-6, 1e-8, 1e-5, 1e-6],
    );
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    for threads in [2, 4] {
        let opts = base.clone().with_threads(threads);
        let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
        assert_bitwise(&serial, &sharded, &format!("per-instance tols, threads={threads}"));
    }
}

/// A wrong-length tolerance vector is rejected at the pooled entry too.
#[test]
#[should_panic(expected = "atol")]
fn pooled_rejects_mismatched_tolerances() {
    let (sys, y0, grid) = het_vdp(4);
    let mut opts = SolveOptions::new(MethodId::DOPRI5).with_threads(2);
    opts.tols = Tolerances::per_instance(vec![1e-6; 3], vec![1e-6; 3]);
    solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
}

/// The joint loop with sharded row-update passes (including the fused
/// error-norm partials) matches the serial joint loop bitwise on both
/// pool kinds — the shared controller reduction stays on the
/// coordinator, in row order.
#[test]
fn joint_pooled_matches_serial_bitwise() {
    let mus = vec![1.0, 5.0, 10.0, 20.0, 2.0];
    let b = mus.len();
    let sys = VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], b);
    let grid = TimeGrid::linspace_shared(b, 0.0, 10.0, 20);
    for m in [MethodId::DOPRI5, MethodId::FEHLBERG45] {
        let base =
            SolveOptions::new(m).with_tols(1e-6, 1e-6).with_max_steps(1_000_000).with_trace();
        let serial = solve_ivp_joint(&sys, &y0, &grid, &base);
        assert!(serial.all_success());
        for threads in [2, 3, 8] {
            for kind in [PoolKind::Scoped, PoolKind::Persistent] {
                let opts = base.clone().with_threads(threads).with_pool(kind);
                let sharded = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
                assert_eq!(sharded.exec_stats.pool_kind, kind, "joint {m:?}");
                assert_bitwise(
                    &serial,
                    &sharded,
                    &format!("joint {m:?} {kind:?} threads={threads}"),
                );
            }
        }
    }
}

/// Sharding composes with the rode `eval_inactive = false` extension.
#[test]
fn skip_inactive_sharded_bitwise() {
    let (sys, y0, grid) = het_vdp(6);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(100_000)
        .skip_inactive();
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
    let sharded = solve_ivp_parallel_pooled(&sys, &y0, &grid, &base.clone().with_threads(3));
    assert_bitwise(&serial, &sharded, "skip-inactive");
}
