//! Cross-module integration: the coordinator running each engine on the
//! same workload must return consistent results; AOT and native engines
//! must agree numerically.

use rode::coordinator::{
    AotEngine, Coordinator, NativeEngine, ProblemSpec, ServiceConfig, SolveRequest,
};
use rode::prelude::*;
use std::time::Duration;

fn vdp_req(id: u64, mu: f64, n_eval: usize, t1: f64) -> SolveRequest {
    let mut r = SolveRequest::new(
        ProblemSpec::Vdp { mu },
        vec![2.0, 0.0],
        (0..n_eval).map(|k| t1 * k as f64 / (n_eval - 1) as f64).collect(),
    );
    r.id = id;
    r
}

fn artifacts_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_str().unwrap().to_string())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn aot_engine_through_coordinator() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
        move || Box::new(AotEngine::open(&dir).expect("open AOT engine")),
    );
    let rxs: Vec<_> = (0..8)
        .map(|i| coord.submit(vdp_req(0, 1.0 + i as f64, 20, 5.0)))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.status, Some(Status::Success), "engine={}", resp.engine);
        assert_eq!(resp.engine, "aot-pjrt");
        assert_eq!(resp.ys.len(), 40);
        assert!(resp.ys.iter().all(|v| v.is_finite()));
        assert!(resp.stats.n_steps > 0);
    }
}

#[test]
fn aot_and_native_engines_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let native = Coordinator::spawn(
        ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
        || Box::new(NativeEngine::default()),
    );
    let aot = Coordinator::spawn(
        ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
        move || Box::new(AotEngine::open(&dir).expect("open AOT engine")),
    );
    let reqs: Vec<SolveRequest> =
        (0..4).map(|i| vdp_req(0, 1.0 + 2.0 * i as f64, 20, 5.0)).collect();
    let r_native: Vec<_> = reqs
        .iter()
        .map(|r| native.solve_blocking(r.clone()).expect("native"))
        .collect();
    let r_aot: Vec<_> =
        reqs.iter().map(|r| aot.solve_blocking(r.clone()).expect("aot")).collect();
    for (n, a) in r_native.iter().zip(&r_aot) {
        assert_eq!(n.status, Some(Status::Success));
        assert_eq!(a.status, Some(Status::Success));
        let max_diff = n
            .ys
            .iter()
            .zip(&a.ys)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(max_diff < 5e-3, "native vs AOT max diff {max_diff}");
    }
}

#[test]
fn aot_engine_pads_partial_batches() {
    // 3 requests against a b=8 artifact: padding must not corrupt results.
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
        move || Box::new(AotEngine::open(&dir).expect("open")),
    );
    let rxs: Vec<_> = (0..3).map(|i| coord.submit(vdp_req(0, 2.0 + i as f64, 20, 4.0))).collect();
    let mut trajectories = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.status, Some(Status::Success));
        assert!(resp.stats.n_steps > 0);
        trajectories.push(resp.ys);
    }
    // Different μ ⇒ different trajectories (padding must not smear the
    // last row over real requests).
    for i in 0..3 {
        for j in (i + 1)..3 {
            let max_diff = trajectories[i]
                .iter()
                .zip(&trajectories[j])
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(max_diff > 1e-3, "instances {i} and {j} identical");
        }
    }
}

#[test]
fn throughput_counters_track_work() {
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            ..ServiceConfig::default()
        },
        || Box::new(NativeEngine::default()),
    );
    let rxs: Vec<_> =
        (0..32).map(|i| coord.submit(vdp_req(0, 1.0 + (i % 4) as f64, 10, 3.0))).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("response");
    }
    let m = coord.metrics();
    use std::sync::atomic::Ordering;
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 32);
    assert!(m.batches_dispatched.load(Ordering::Relaxed) <= 32);
    assert!(m.mean_batch_size() >= 1.0);
    assert!(m.solver_steps_sum.load(Ordering::Relaxed) > 0);
}
