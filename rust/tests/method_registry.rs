//! End-to-end registry behavior: runtime method registration feeding the
//! ordinary solve entry points, and per-request method routing through the
//! coordinator. Built-in registry invariants live in
//! `tableau_cross_check.rs`; these tests exercise the *open* part of the
//! registry (methods the crate has never heard of) and the service path.

use rode::coordinator::{
    Batch, BucketKey, NativeEngine, ProblemSpec, SolveEngine, SolveRequest,
};
use rode::coordinator::{Coordinator, ServiceConfig};
use rode::prelude::*;
use rode::problems::ExponentialDecay;
use rode::solver::tableau::{DenseOutput, Tableau};
use std::time::Duration;

/// Heun–Euler 2(1): the smallest embedded explicit pair. Not shipped as a
/// built-in, which is exactly why it makes a good runtime-registration
/// probe — the solver has never seen it before this test registers it.
static HEUN_EULER21: Tableau = Tableau {
    name: "heun_euler21",
    stages: 2,
    order: 2,
    err_order: 1,
    a: &[1.0],
    b: &[0.5, 0.5],
    // b − b̂ with b̂ = [1, 0] (the embedded Euler solution).
    b_err: &[-0.5, 0.5],
    c: &[0.0, 1.0],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

#[test]
fn runtime_registration_roundtrip() {
    let id = register_method_with_aliases("heun_euler21", &["he21"], &HEUN_EULER21)
        .expect("register");

    // Name and alias resolve to the same slot; display echoes the name.
    assert_eq!(MethodId::parse("heun_euler21"), Some(id));
    assert_eq!(MethodId::parse("HE21"), Some(id));
    assert_eq!(id.to_string(), "heun_euler21");
    assert!(!id.is_implicit());
    assert!(MethodId::all().contains(&id));

    // The compiled tableau is slot-cached: every lookup returns the same
    // 'static allocation (this is what keys the engines' kernel reuse).
    assert!(std::ptr::eq(id.compiled(), id.compiled()));
    assert!(std::ptr::eq(id.tableau(), &HEUN_EULER21));

    // The registered method drives a real solve through the normal entry
    // point. ẏ = −y from 1.0: compare against e^{−t}.
    let sys = ExponentialDecay::new(vec![1.0], 1);
    let y0 = BatchVec::from_rows(&[vec![1.0]]);
    let grid = TimeGrid::from_rows(&[vec![0.0, 0.5, 1.0]]);
    let opts = SolveOptions::new(id).with_tols(1e-8, 1e-8);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert_eq!(sol.status[0], Status::Success);
    assert!((sol.y(0, 2)[0] - (-1.0f64).exp()).abs() < 1e-5);

    // Names are never re-bound: registering the same name (or a built-in
    // name) fails instead of shadowing.
    assert!(matches!(
        register_method("heun_euler21", &HEUN_EULER21),
        Err(RegisterError::NameTaken(_))
    ));
    assert!(matches!(
        register_method("dopri5", &HEUN_EULER21),
        Err(RegisterError::NameTaken(_))
    ));
}

fn vdp_req(id: u64, mu: f64, method: Option<MethodId>) -> SolveRequest {
    let mut r = SolveRequest::new(
        ProblemSpec::Vdp { mu },
        vec![2.0, 0.0],
        (0..10).map(|k| k as f64 * 0.45).collect(),
    );
    r.id = id;
    r.method = method;
    r
}

/// One service run carrying three method buckets at once: easy traffic on
/// the engine default (dopri5) plus stiff traffic routed to trbdf2 and
/// kvaerno43. Each bucket must flush separately, resolve to its own
/// method, and reproduce a standalone single-bucket solve bitwise.
#[test]
fn coordinator_routes_methods_per_request() {
    let groups: Vec<(Option<MethodId>, Vec<SolveRequest>)> = vec![
        (None, (1..=3).map(|i| vdp_req(i, 1.5, None)).collect()),
        (
            Some(MethodId::TRBDF2),
            (11..=13).map(|i| vdp_req(i, 120.0, Some(MethodId::TRBDF2))).collect(),
        ),
        (
            Some(MethodId::KVAERNO43),
            (21..=23).map(|i| vdp_req(i, 120.0, Some(MethodId::KVAERNO43))).collect(),
        ),
    ];

    // max_batch = group size, long deadline: each group flushes exactly
    // when its third request arrives, so batch composition is
    // deterministic and comparable to the standalone solves below.
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(60),
            ..ServiceConfig::default()
        },
        || Box::new(NativeEngine::default()),
    );
    let mut rxs = Vec::new();
    for (_, reqs) in &groups {
        for r in reqs {
            rxs.push(coord.submit(r.clone()));
        }
    }
    let mut responses = Vec::new();
    for rx in rxs {
        responses.push(rx.recv_timeout(Duration::from_secs(120)).expect("response"));
    }
    assert_eq!(coord.metrics().batches_dispatched.load(std::sync::atomic::Ordering::Relaxed), 3);
    drop(coord);

    // Every request succeeded and reports the method its bucket resolved
    // to (the override when set, the engine default otherwise).
    for (gi, (method, reqs)) in groups.iter().enumerate() {
        let expect = method.unwrap_or(MethodId::DOPRI5);
        for r in reqs {
            let resp = responses.iter().find(|x| x.id == r.id).expect("id");
            assert_eq!(resp.status, Some(Status::Success), "group {gi} id {}", r.id);
            assert_eq!(resp.method, Some(expect), "group {gi} id {}", r.id);
        }
    }
    // The implicit buckets actually ran Newton (Jacobian builds), the
    // explicit bucket did not.
    for r in &responses {
        let implicit = r.method.map(|m| m.is_implicit()).unwrap_or(false);
        assert_eq!(r.stats.n_jac_evals > 0, implicit, "id {}", r.id);
    }

    // Routed service output is bitwise-identical to solving the same
    // bucket standalone with the same engine defaults.
    for (method, reqs) in &groups {
        let mut engine = NativeEngine::default();
        let batch = Batch {
            key: BucketKey::of(&reqs[0]),
            requests: reqs.clone(),
            oldest_wait: Duration::ZERO,
        };
        assert_eq!(batch.key.method, *method);
        for standalone in engine.solve(&batch).expect("standalone solve") {
            let routed = responses.iter().find(|x| x.id == standalone.id).expect("id");
            assert_eq!(routed.stats, standalone.stats, "id {}", standalone.id);
            let a: Vec<u64> = routed.ys.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = standalone.ys.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "trajectory of id {} differs", standalone.id);
        }
    }
}
