//! Fault tolerance of the serving layer: scripted engine panics, engine
//! errors and artificial delays driven through the real coordinator.
//!
//! What must hold (the failure-domain contract of `coordinator/service`):
//!
//! - a panicking batch fails *only* its own requests, with a structured
//!   [`ServiceError::WorkerPanic`], and the worker rebuilds its engine
//!   and keeps serving;
//! - an engine `Err` is distinguishable from a genuine non-finite solve;
//! - a stiff request that dies on the explicit default is transparently
//!   escalated to the implicit fallback and succeeds, with the
//!   escalation visible in the response and the metrics;
//! - a full queue sheds with [`ServiceError::Overloaded`] (low priority
//!   first), expired deadlines are dropped at dispatch, and no receiver
//!   ever hangs — not even when the worker is dead or shutting down;
//! - in a multi-worker fleet, one worker tombstoning moves its traffic
//!   onto survivors (`WorkerUnavailable` only when the whole fleet is
//!   dead), and a wrong call by the proactive stiffness classifier is
//!   caught by the reactive escalation safety net.
//!
//! Tests that count engine builds or rely on scripted fault ordering pin
//! `workers: 1`; the fleet tests pin explicit worker counts.

use rode::coordinator::{
    Batch, ClassifierPolicy, Coordinator, NativeEngine, Priority, ProblemSpec, RetryPolicy,
    ServiceConfig, ServiceError, SolveEngine, SolveRequest, SolveResponse, WorkerHealth,
};
use rode::solver::{MethodId, SolveOptions, Status};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Once};
use std::time::Duration;

/// Injected panics are expected output here; silence the default panic
/// hook's backtrace spam for payloads carrying our marker, once per
/// process.
fn quiet_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.starts_with("injected:"))
                .or_else(|| {
                    info.payload().downcast_ref::<&str>().map(|s| s.starts_with("injected:"))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

/// One scripted behavior for one `solve` call.
#[derive(Debug, Clone, Copy)]
enum Fault {
    /// Delegate to the inner engine.
    Pass,
    /// Panic with `"injected: <msg>"`.
    Panic(&'static str),
    /// Return `Err(<msg>)` for the whole batch.
    Fail(&'static str),
    /// Sleep this many milliseconds, then delegate.
    Delay(u64),
}

/// A [`SolveEngine`] that pops one [`Fault`] per solve from a script
/// shared with the test (and with rebuilt instances — a panic must not
/// reset the script).
struct FaultInjectingEngine {
    inner: NativeEngine,
    script: Arc<Mutex<VecDeque<Fault>>>,
}

impl SolveEngine for FaultInjectingEngine {
    fn name(&self) -> &'static str {
        "fault-injecting"
    }

    fn solve(&mut self, batch: &Batch) -> anyhow::Result<Vec<SolveResponse>> {
        let fault = self.script.lock().unwrap().pop_front().unwrap_or(Fault::Pass);
        match fault {
            Fault::Pass => self.inner.solve(batch),
            Fault::Panic(msg) => panic!("injected: {msg}"),
            Fault::Fail(msg) => Err(anyhow::anyhow!("{msg}")),
            Fault::Delay(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.solve(batch)
            }
        }
    }
}

/// Coordinator over a scripted engine; returns the engine-build counter
/// so tests can assert on rebuilds.
fn scripted(cfg: ServiceConfig, faults: Vec<Fault>) -> (Coordinator, Arc<AtomicUsize>) {
    quiet_injected_panics();
    let script = Arc::new(Mutex::new(VecDeque::from(faults)));
    let builds = Arc::new(AtomicUsize::new(0));
    let builds_in_factory = builds.clone();
    let coord = Coordinator::spawn(cfg, move || -> Box<dyn SolveEngine> {
        builds_in_factory.fetch_add(1, Ordering::SeqCst);
        Box::new(FaultInjectingEngine { inner: NativeEngine::default(), script: script.clone() })
    });
    (coord, builds)
}

fn easy_req(mu: f64) -> SolveRequest {
    SolveRequest::new(
        ProblemSpec::Vdp { mu },
        vec![2.0, 0.0],
        (0..10).map(|k| k as f64 * 0.3).collect(),
    )
}

fn cfg_no_retry(max_batch: usize, wait_ms: u64) -> ServiceConfig {
    ServiceConfig {
        max_batch,
        max_wait: Duration::from_millis(wait_ms),
        retry: RetryPolicy::disabled(),
        // One worker: these tests count engine builds / rely on the shared
        // fault script being consumed in submission order.
        workers: 1,
        ..ServiceConfig::default()
    }
}

/// The same options `tests/stiff_regression.rs` pins: μ = 1000 over
/// [0, 400] underflows on dopri5 (min_dt held above the stability
/// ceiling) and succeeds on trbdf2.
fn stiff_wall_opts() -> SolveOptions {
    let mut opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-4)
        .with_dt0(0.01)
        .with_max_steps(500_000);
    opts.min_dt_rel = 1e-5;
    opts
}

fn stiff_req() -> SolveRequest {
    SolveRequest::new(
        ProblemSpec::Vdp { mu: 1000.0 },
        vec![2.0, 0.0],
        (0..5).map(|k| k as f64 * 100.0).collect(),
    )
}

fn recv(rx: std::sync::mpsc::Receiver<SolveResponse>) -> SolveResponse {
    rx.recv_timeout(Duration::from_secs(60)).expect("receiver must resolve")
}

#[test]
fn worker_survives_engine_panic_and_rebuilds() {
    let (coord, builds) = scripted(cfg_no_retry(1, 1), vec![Fault::Panic("boom")]);

    // First request hits the scripted panic: structured failure, no
    // trajectory, no solver status.
    let resp = recv(coord.submit(easy_req(2.0)));
    match &resp.error {
        Some(ServiceError::WorkerPanic { detail }) => {
            assert!(detail.contains("injected: boom"), "detail: {detail}")
        }
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
    assert_eq!(resp.status, None);
    assert!(resp.ys.is_empty());

    // The worker is still alive and serving on a rebuilt engine.
    let resp = recv(coord.submit(easy_req(2.0)));
    assert!(resp.is_success(), "post-panic request failed: {:?}", resp.error);

    let m = coord.metrics();
    assert_eq!(builds.load(Ordering::SeqCst), 2, "engine must be rebuilt after the panic");
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_failed.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_inflight.load(Ordering::Relaxed), 0);
}

#[test]
fn panic_fails_only_its_own_batch() {
    // Batch of two poisoned requests, then a batch of two healthy ones:
    // the blast radius of the panic is exactly the first batch.
    let (coord, _) = scripted(cfg_no_retry(2, 1), vec![Fault::Panic("poisoned batch")]);

    let poisoned: Vec<_> = (0..2).map(|_| coord.submit(easy_req(1.5))).collect();
    let first: Vec<SolveResponse> = poisoned.into_iter().map(recv).collect();
    for resp in &first {
        assert!(
            matches!(resp.error, Some(ServiceError::WorkerPanic { .. })),
            "expected WorkerPanic, got {:?}",
            resp.error
        );
    }

    let healthy: Vec<_> = (0..2).map(|_| coord.submit(easy_req(1.5))).collect();
    for rx in healthy {
        assert!(recv(rx).is_success());
    }
    let m = coord.metrics();
    assert_eq!(m.requests_failed.load(Ordering::Relaxed), 2);
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 2);
}

#[test]
fn engine_error_is_not_a_solver_failure() {
    let (coord, builds) = scripted(cfg_no_retry(1, 1), vec![Fault::Fail("no dynamics loaded")]);

    // Engine `Err`: a service-level failure with the engine's message...
    let resp = recv(coord.submit(easy_req(2.0)));
    match &resp.error {
        Some(ServiceError::EngineError { detail }) => {
            assert!(detail.contains("no dynamics loaded"), "detail: {detail}")
        }
        other => panic!("expected EngineError, got {other:?}"),
    }
    assert_eq!(resp.status, None);

    // ...while a genuinely non-finite solve is a *completed* request with
    // a solver status — the two are no longer conflated.
    let mut nan_req = easy_req(2.0);
    nan_req.y0 = vec![f64::NAN, 0.0];
    let resp = recv(coord.submit(nan_req));
    assert_eq!(resp.error, None);
    assert_eq!(resp.status, Some(Status::NonFinite));

    let m = coord.metrics();
    assert_eq!(m.requests_failed.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
    // An engine Err keeps the engine: no rebuild, no panic counted.
    assert_eq!(builds.load(Ordering::SeqCst), 1);
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 0);
}

#[test]
fn stiff_request_escalates_to_implicit_and_succeeds() {
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..ServiceConfig::default() // retry: trbdf2, 1 attempt
        },
        || Box::new(NativeEngine::new(stiff_wall_opts())),
    );
    let resp = recv(coord.submit(stiff_req()));
    assert!(resp.is_success(), "escalated solve failed: {:?}/{:?}", resp.status, resp.error);
    assert_eq!(resp.method, Some(MethodId::TRBDF2), "must have been solved by the fallback");
    assert_eq!(resp.escalated_from, Some(MethodId::DOPRI5), "escalation must be visible");
    assert!(resp.ys.iter().all(|v| v.is_finite()));

    let m = coord.metrics();
    assert_eq!(m.requests_retried.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_failed.load(Ordering::Relaxed), 0);
    // One terminal response despite two solves.
    assert_eq!(m.requests_submitted.load(Ordering::Relaxed), 1);
}

#[test]
fn retry_disabled_returns_the_explicit_failure() {
    let coord = Coordinator::spawn(
        cfg_no_retry(1, 1),
        || Box::new(NativeEngine::new(stiff_wall_opts())),
    );
    let resp = recv(coord.submit(stiff_req()));
    // The solver ran and failed — a completed request, not a service
    // error, and no escalation happened.
    assert_eq!(resp.error, None);
    assert_eq!(resp.status, Some(Status::DtUnderflow));
    assert_eq!(resp.method, Some(MethodId::DOPRI5));
    assert_eq!(resp.escalated_from, None);
    assert_eq!(coord.metrics().requests_retried.load(Ordering::Relaxed), 0);
}

#[test]
fn full_queue_sheds_with_overloaded() {
    // One slow batch occupies the worker while a flood arrives: the
    // bounded queue admits up to its Normal-class limit and sheds the
    // rest immediately.
    let max_queue = 4;
    let (coord, _) = scripted(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue,
            retry: RetryPolicy::disabled(),
            workers: 1,
            ..ServiceConfig::default()
        },
        vec![Fault::Delay(300)],
    );
    let slow = coord.submit(easy_req(1.0));
    // Let the worker pick the slow request up before flooding.
    std::thread::sleep(Duration::from_millis(100));

    let flood: Vec<_> = (0..10).map(|_| coord.submit(easy_req(1.0))).collect();
    let responses: Vec<SolveResponse> = flood.into_iter().map(recv).collect();
    let shed: Vec<_> = responses
        .iter()
        .filter(|r| matches!(r.error, Some(ServiceError::Overloaded { .. })))
        .collect();
    assert!(!shed.is_empty(), "a 10-deep flood over max_queue=4 must shed");
    for r in &shed {
        if let Some(ServiceError::Overloaded { inflight, max_queue: mq }) = &r.error {
            assert_eq!(*mq, max_queue);
            assert!(*inflight >= 1);
        }
    }
    assert!(recv(slow).is_success());

    // Accounting: every submission is terminal in exactly one class.
    let m = coord.metrics();
    let submitted = m.requests_submitted.load(Ordering::Relaxed);
    let completed = m.requests_completed.load(Ordering::Relaxed);
    let failed = m.requests_failed.load(Ordering::Relaxed);
    let shed_n = m.requests_shed.load(Ordering::Relaxed);
    let expired = m.requests_deadline_expired.load(Ordering::Relaxed);
    assert_eq!(submitted, 11);
    assert_eq!(shed_n, shed.len() as u64);
    assert_eq!(completed + failed + shed_n + expired, submitted);
    assert_eq!(m.requests_inflight.load(Ordering::Relaxed), 0);
}

#[test]
fn low_priority_sheds_before_high() {
    // Fill the queue to the Normal limit (max_queue − max_queue/8 = 7),
    // then probe each class at the same instant of load: Low is shed,
    // High still fits in the reserved headroom, a second High overflows.
    let (coord, _) = scripted(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue: 8,
            retry: RetryPolicy::disabled(),
            workers: 1,
            ..ServiceConfig::default()
        },
        vec![Fault::Delay(500)],
    );
    let occupants: Vec<_> = (0..7).map(|_| coord.submit(easy_req(1.0))).collect();
    std::thread::sleep(Duration::from_millis(100));

    let low = recv(coord.submit(easy_req(1.0).with_priority(Priority::Low)));
    assert!(
        matches!(low.error, Some(ServiceError::Overloaded { .. })),
        "low priority must be shed at 7/8 load, got {:?}",
        low.error
    );
    let high = coord.submit(easy_req(1.0).with_priority(Priority::High));
    let second_high = recv(coord.submit(easy_req(1.0).with_priority(Priority::High)));
    assert!(
        matches!(second_high.error, Some(ServiceError::Overloaded { .. })),
        "the queue is full at 8/8 even for high priority, got {:?}",
        second_high.error
    );
    assert!(recv(high).is_success(), "high priority fits the reserved headroom");
    for rx in occupants {
        assert!(recv(rx).is_success());
    }
    assert_eq!(coord.metrics().requests_shed.load(Ordering::Relaxed), 2);
}

#[test]
fn expired_deadline_is_dropped_at_dispatch() {
    // Two requests share one bucket; the batch flushes on the 50 ms wait
    // timer, by which time the 1 ms deadline is long gone: the expired
    // request never occupies a batch slot, its neighbor still solves.
    let (coord, _) = scripted(cfg_no_retry(64, 50), vec![]);
    let doomed = coord.submit(easy_req(1.0).with_deadline(Duration::from_millis(1)));
    let healthy = coord.submit(easy_req(1.0));

    let resp = recv(doomed);
    assert_eq!(resp.error, Some(ServiceError::DeadlineExpired));
    assert_eq!(resp.status, None);
    assert!(recv(healthy).is_success());

    let m = coord.metrics();
    assert_eq!(m.requests_deadline_expired.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 1);
    // The dispatched batch carried only the one live request.
    assert_eq!(m.batch_size_sum.load(Ordering::Relaxed), 1);
}

#[test]
fn shutdown_under_load_strands_no_receiver() {
    // Slow batches + shutdown mid-flight: every receiver must resolve —
    // solved during the drain or failed with ShuttingDown — never hang.
    let (coord, _) = scripted(
        cfg_no_retry(1, 1),
        vec![Fault::Delay(100), Fault::Delay(100), Fault::Delay(100)],
    );
    let rxs: Vec<_> = (0..6).map(|_| coord.submit(easy_req(1.0))).collect();
    drop(coord); // begins shutdown while work is still queued
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("stranded receiver");
        assert!(
            resp.is_success() || resp.error == Some(ServiceError::ShuttingDown),
            "unexpected terminal state: {:?}/{:?}",
            resp.status,
            resp.error
        );
    }
}

#[test]
fn dead_worker_fails_submissions_immediately() {
    quiet_injected_panics();
    // The factory itself panics: no engine can ever exist. Submissions
    // must get an immediate WorkerUnavailable — not a receiver that never
    // fires.
    let coord = Coordinator::spawn(
        ServiceConfig { max_batch: 1, ..ServiceConfig::default() },
        || -> Box<dyn SolveEngine> { panic!("injected: factory down") },
    );
    // Give the worker a moment to hit the factory panic.
    std::thread::sleep(Duration::from_millis(100));
    for _ in 0..3 {
        let resp = recv(coord.submit(easy_req(1.0)));
        assert_eq!(resp.error, Some(ServiceError::WorkerUnavailable));
    }
    assert!(coord.metrics().worker_panics.load(Ordering::Relaxed) >= 1);
}

#[test]
fn failed_rebuild_degrades_to_immediate_errors() {
    quiet_injected_panics();
    // First build succeeds; the engine panics on its first batch; the
    // rebuild panics too. The worker must degrade to serving immediate
    // failures rather than dying silently.
    let builds = Arc::new(AtomicUsize::new(0));
    let builds_in_factory = builds.clone();
    let coord = Coordinator::spawn(
        cfg_no_retry(1, 1),
        move || -> Box<dyn SolveEngine> {
            if builds_in_factory.fetch_add(1, Ordering::SeqCst) > 0 {
                panic!("injected: rebuild refused");
            }
            let script = Arc::new(Mutex::new(VecDeque::from(vec![Fault::Panic("one shot")])));
            Box::new(FaultInjectingEngine { inner: NativeEngine::default(), script })
        },
    );
    let resp = recv(coord.submit(easy_req(1.0)));
    assert!(matches!(resp.error, Some(ServiceError::WorkerPanic { .. })));
    // Both the engine panic and the factory panic were absorbed.
    std::thread::sleep(Duration::from_millis(50));
    let resp = recv(coord.submit(easy_req(1.0)));
    assert_eq!(resp.error, Some(ServiceError::WorkerUnavailable));
    assert_eq!(builds.load(Ordering::SeqCst), 2);
    assert_eq!(coord.metrics().worker_panics.load(Ordering::Relaxed), 2);
}

// ---------------------------------------------------------------- fleet

#[test]
fn fleet_one_worker_tombstones_and_survivors_serve() {
    quiet_injected_panics();
    // Two workers (builds 1 and 2). The shared script panics the first
    // solve; the factory refuses the rebuild (build 3), so exactly the
    // worker that took the poisoned batch tombstones. Later traffic for
    // the same bucket must land on the survivor — not fail.
    let script = Arc::new(Mutex::new(VecDeque::from(vec![Fault::Panic("mid-replay")])));
    let builds = Arc::new(AtomicUsize::new(0));
    let builds_in_factory = builds.clone();
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            retry: RetryPolicy::disabled(),
            workers: 2,
            ..ServiceConfig::default()
        },
        move || -> Box<dyn SolveEngine> {
            if builds_in_factory.fetch_add(1, Ordering::SeqCst) >= 2 {
                panic!("injected: rebuild refused");
            }
            let script = script.clone();
            Box::new(FaultInjectingEngine { inner: NativeEngine::default(), script })
        },
    );
    assert_eq!(coord.workers(), 2);

    // Blast radius: exactly the poisoned batch fails...
    let resp = recv(coord.submit(easy_req(1.5)));
    assert!(matches!(resp.error, Some(ServiceError::WorkerPanic { .. })));
    std::thread::sleep(Duration::from_millis(100)); // let the rebuild fail

    // ...the dead worker is tombstoned, and its bucket fails over.
    assert_eq!(coord.alive_workers(), 1);
    let tombstoned = (0..2)
        .filter(|&i| coord.worker_health(i) == WorkerHealth::Tombstoned)
        .count();
    assert_eq!(tombstoned, 1);
    for _ in 0..3 {
        let resp = recv(coord.submit(easy_req(1.5)));
        assert!(resp.is_success(), "failover request failed: {:?}", resp.error);
    }

    let m = coord.metrics();
    // One engine panic + one factory panic, split across the breakdown.
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 2);
    assert_eq!((0..2).map(|i| m.worker_panics_of(i)).sum::<u64>(), 2);
    assert_eq!(m.requests_failed.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 3);
    assert_eq!(m.requests_inflight.load(Ordering::Relaxed), 0);
}

#[test]
fn fleet_dead_factory_on_one_worker_fails_over() {
    quiet_injected_panics();
    // The factory works once, then refuses: one worker never gets an
    // engine and tombstones at startup. Every request still succeeds on
    // the survivor — a half-dead fleet is degraded, not down.
    let builds = Arc::new(AtomicUsize::new(0));
    let builds_in_factory = builds.clone();
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            retry: RetryPolicy::disabled(),
            workers: 2,
            ..ServiceConfig::default()
        },
        move || -> Box<dyn SolveEngine> {
            if builds_in_factory.fetch_add(1, Ordering::SeqCst) >= 1 {
                panic!("injected: factory down");
            }
            Box::new(NativeEngine::default())
        },
    );
    std::thread::sleep(Duration::from_millis(100)); // let startup settle
    assert_eq!(coord.alive_workers(), 1);

    // Spread traffic over several buckets so both halves of the hash
    // space are exercised; none may see WorkerUnavailable.
    let rxs: Vec<_> = (0..8)
        .map(|k| {
            let mut r = easy_req(1.0 + k as f64 * 0.1);
            r.t_eval = (0..10 + k).map(|j| j as f64 * 0.3).collect();
            coord.submit(r)
        })
        .collect();
    for rx in rxs {
        let resp = recv(rx);
        assert!(resp.is_success(), "degraded fleet dropped a request: {:?}", resp.error);
    }
    assert_eq!(coord.metrics().requests_completed.load(Ordering::Relaxed), 8);
}

#[test]
fn fleet_fully_dead_returns_worker_unavailable() {
    quiet_injected_panics();
    // Both factories refuse: only now — with zero alive workers — may the
    // service answer WorkerUnavailable.
    let coord = Coordinator::spawn(
        ServiceConfig { workers: 2, ..ServiceConfig::default() },
        || -> Box<dyn SolveEngine> { panic!("injected: factory down") },
    );
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(coord.alive_workers(), 0);
    for i in 0..2 {
        assert_eq!(coord.worker_health(i), WorkerHealth::Tombstoned);
    }
    for _ in 0..3 {
        let resp = recv(coord.submit(easy_req(1.0)));
        assert_eq!(resp.error, Some(ServiceError::WorkerUnavailable));
    }
    assert_eq!(coord.metrics().requests_inflight.load(Ordering::Relaxed), 0);
}

#[test]
fn fleet_shutdown_under_load_strands_no_receiver() {
    // Three workers, slow batches, a scripted panic, and shutdown while
    // requests are still in flight (some mid-failover): every receiver
    // must resolve with a terminal response — never hang.
    let (coord, _) = scripted(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            retry: RetryPolicy::disabled(),
            workers: 3,
            ..ServiceConfig::default()
        },
        vec![Fault::Delay(100), Fault::Panic("mid-shutdown"), Fault::Delay(100)],
    );
    let rxs: Vec<_> = (0..12)
        .map(|k| {
            let mut r = easy_req(1.0);
            r.t_eval = (0..8 + (k % 4)).map(|j| j as f64 * 0.3).collect();
            coord.submit(r)
        })
        .collect();
    drop(coord); // begins shutdown while work is queued on all workers
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("stranded receiver");
        assert!(
            resp.is_success()
                || matches!(
                    resp.error,
                    Some(ServiceError::ShuttingDown)
                        | Some(ServiceError::WorkerPanic { .. })
                        | Some(ServiceError::WorkerUnavailable)
                ),
            "unexpected terminal state: {:?}/{:?}",
            resp.status,
            resp.error
        );
    }
}

#[test]
fn fleet_metrics_taxonomy_is_exact_under_concurrency() {
    quiet_injected_panics();
    // Four workers, four submitter threads, mixed traffic (panicking
    // batches, NaN solves, tight deadlines, priorities). Whatever the
    // interleaving, the terminal classes must partition submissions
    // exactly — no request double-counted or lost.
    let (coord, _) = scripted(
        ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            retry: RetryPolicy::disabled(),
            workers: 4,
            ..ServiceConfig::default()
        },
        vec![Fault::Panic("taxonomy"), Fault::Delay(50), Fault::Panic("taxonomy")],
    );
    let coord = Arc::new(coord);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let rxs: Vec<_> = (0..25)
                    .map(|k| {
                        let mut r = easy_req(1.0 + (k % 5) as f64);
                        r.t_eval = (0..6 + (k % 3)).map(|j| j as f64 * 0.3).collect();
                        if k % 7 == 0 {
                            r.y0 = vec![f64::NAN, 0.0]; // completed, NonFinite
                        }
                        if k % 11 == 3 {
                            r = r.with_deadline(Duration::from_micros(1));
                        }
                        if t % 2 == 0 && k % 13 == 5 {
                            r = r.with_priority(Priority::Low);
                        }
                        coord.submit(r)
                    })
                    .collect();
                for rx in rxs {
                    recv(rx); // any terminal response; must not hang
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let m = coord.metrics();
    let submitted = m.requests_submitted.load(Ordering::Relaxed);
    let completed = m.requests_completed.load(Ordering::Relaxed);
    let failed = m.requests_failed.load(Ordering::Relaxed);
    let shed = m.requests_shed.load(Ordering::Relaxed);
    let expired = m.requests_deadline_expired.load(Ordering::Relaxed);
    assert_eq!(submitted, 100);
    assert_eq!(
        completed + failed + shed + expired,
        submitted,
        "taxonomy must partition: {completed}+{failed}+{shed}+{expired} != {submitted}"
    );
    assert_eq!(m.requests_inflight.load(Ordering::Relaxed), 0);
    // The per-worker breakdown reconciles with the fleet total.
    let panics = m.worker_panics.load(Ordering::Relaxed);
    assert_eq!(panics, 2, "both scripted panics must be consumed");
    assert_eq!((0..4).map(|i| m.worker_panics_of(i)).sum::<u64>(), panics);
    assert_eq!(
        (0..4).map(|i| m.worker_rebuilds_of(i)).sum::<u64>(),
        m.worker_rebuilds.load(Ordering::Relaxed)
    );
}

// ----------------------------------------------------- classifier

/// Classifier on, but with a step budget so generous nothing looks stiff:
/// the stiff request is *misclassified* as explicit, dies on dopri5, and
/// the reactive escalation safety net still lands it.
#[test]
fn misclassified_stiff_request_is_caught_by_escalation() {
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            workers: 1,
            classifier: ClassifierPolicy {
                enabled: true,
                step_budget: 1e12, // nothing ever classifies as stiff
                ..ClassifierPolicy::default()
            },
            ..ServiceConfig::default() // retry: trbdf2, 1 attempt
        },
        || Box::new(NativeEngine::new(stiff_wall_opts())),
    );
    let resp = recv(coord.submit(stiff_req()));
    assert!(resp.is_success(), "safety net failed: {:?}/{:?}", resp.status, resp.error);
    assert_eq!(resp.method, Some(MethodId::TRBDF2));
    assert_eq!(resp.escalated_from, Some(MethodId::DOPRI5), "must be the reactive path");
    assert!(!resp.classified_stiff);

    let m = coord.metrics();
    assert_eq!(m.classified_stiff.load(Ordering::Relaxed), 0);
    assert_eq!(m.classifier_hits.load(Ordering::Relaxed), 0);
    assert_eq!(m.classifier_misses.load(Ordering::Relaxed), 1, "the wrong call is recorded");
    assert_eq!(m.requests_retried.load(Ordering::Relaxed), 1);
}

/// The opposite wrong call: a zero step budget classifies *everything* as
/// stiff. An easy request then solves on the implicit fallback — slower,
/// but still a success; a false positive must never fail a request.
#[test]
fn classifier_false_positive_still_succeeds() {
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            workers: 1,
            classifier: ClassifierPolicy {
                enabled: true,
                step_budget: 0.0, // everything classifies as stiff
                ..ClassifierPolicy::default()
            },
            ..ServiceConfig::default()
        },
        || Box::new(NativeEngine::new(stiff_wall_opts())),
    );
    let resp = recv(coord.submit(easy_req(2.0)));
    assert!(resp.is_success(), "false positive failed: {:?}/{:?}", resp.status, resp.error);
    assert_eq!(resp.method, Some(MethodId::TRBDF2), "routed proactively to the fallback");
    assert!(resp.classified_stiff);
    assert_eq!(resp.escalated_from, None, "no explicit attempt was paid");

    let m = coord.metrics();
    assert_eq!(m.classified_stiff.load(Ordering::Relaxed), 1);
    assert_eq!(m.classifier_hits.load(Ordering::Relaxed), 1);
    assert_eq!(m.requests_retried.load(Ordering::Relaxed), 0);
}

/// The headline contract: with the classifier on, a stiff request solves
/// on the implicit method with *zero* failed explicit attempts, and the
/// reactive retry counter stays untouched.
#[test]
fn classifier_routes_stiff_traffic_with_zero_explicit_failures() {
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            workers: 1,
            classifier: ClassifierPolicy::enabled(),
            retry: RetryPolicy::disabled(), // no safety net: proactive or bust
            ..ServiceConfig::default()
        },
        || Box::new(NativeEngine::new(stiff_wall_opts())),
    );
    // Stiff and easy traffic interleaved: only the stiff ones reroute.
    let stiff_rxs: Vec<_> = (0..3).map(|_| coord.submit(stiff_req())).collect();
    let easy_rxs: Vec<_> = (0..3).map(|_| coord.submit(easy_req(2.0))).collect();
    for rx in stiff_rxs {
        let resp = recv(rx);
        assert!(resp.is_success(), "proactive route failed: {:?}/{:?}", resp.status, resp.error);
        assert_eq!(resp.method, Some(MethodId::TRBDF2));
        assert!(resp.classified_stiff);
        assert_eq!(resp.escalated_from, None);
    }
    for rx in easy_rxs {
        let resp = recv(rx);
        assert!(resp.is_success());
        assert!(!resp.classified_stiff, "easy traffic stays explicit");
        assert_eq!(resp.method, Some(MethodId::DOPRI5));
    }

    let m = coord.metrics();
    assert_eq!(m.classified_stiff.load(Ordering::Relaxed), 3);
    assert_eq!(m.classifier_hits.load(Ordering::Relaxed), 3);
    assert_eq!(m.classifier_misses.load(Ordering::Relaxed), 0);
    assert_eq!(m.requests_retried.load(Ordering::Relaxed), 0, "no reactive retries paid");
    assert_eq!(m.requests_failed.load(Ordering::Relaxed), 0);
}
