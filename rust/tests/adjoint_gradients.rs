//! Property suite for the adjoint family: every way of getting
//! `∂L/∂(y0, θ)` through a solve must agree with central finite
//! differences — and with the other ways.
//!
//! Three modes under test (see `docs/architecture.md`):
//!
//! - **fixed tape** (`rk_forward_tape` / `rk_backward`): exact gradient
//!   of the fixed-step discrete map, so FD is run on that same discrete
//!   map and the agreement is tight. Covers explicit *and* implicit
//!   (DIRK) methods — the implicit backward differentiates through the
//!   Newton solve via the implicit-function theorem.
//! - **adaptive tape** (`rk_forward_tape_adaptive` /
//!   `rk_backward_adaptive`): the recorded step trace is replayed and
//!   differentiated exactly; FD is run on the adaptive forward loss at
//!   tight tolerances.
//! - **backsolve** (`backsolve_adjoint_parallel`): continuous adjoint
//!   with checkpointed state re-solve; compared against FD of a
//!   high-accuracy reference solve.
//!
//! Plus the determinism contract: gradients are **bitwise identical**
//! across pool kinds × thread counts × memory layouts, because the
//! forward trace is bitwise-stable (the solver's own parity contract)
//! and both backward passes are row-serial.

use rode::config::PoolKind;
use rode::prelude::*;
use rode::problems::{ExponentialDecay, Robertson, VdP};
use rode::solver::{
    backsolve_adjoint_parallel, replay_tape, rk_backward, rk_backward_adaptive, rk_forward_tape,
    rk_forward_tape_adaptive, AdjointOptions,
};

/// Build a single-instance system for the given scalar parameter value.
type MakeSys = dyn Fn(f64) -> Box<dyn OdeSystem>;

struct Case {
    name: &'static str,
    make: Box<MakeSys>,
    /// Nominal parameter (fed back through `make` for FD).
    param: Option<f64>,
    y0: Vec<f64>,
    /// Loss weights: `L = Σ_d w[d] · y_d(t1)`.
    w: Vec<f64>,
    t1: f64,
}

fn cases() -> Vec<Case> {
    vec![
        Case {
            name: "linear-decay",
            make: Box::new(|lam| Box::new(ExponentialDecay::new(vec![lam], 1))),
            param: Some(0.8),
            y0: vec![2.0],
            w: vec![1.0],
            t1: 1.5,
        },
        Case {
            name: "vdp",
            make: Box::new(|mu| Box::new(VdP::new(vec![mu]))),
            param: Some(1.2),
            y0: vec![1.2, -0.4],
            w: vec![1.0, -0.3],
            t1: 1.5,
        },
        Case {
            name: "robertson",
            make: Box::new(|_| Box::new(Robertson::new(1))),
            param: None,
            y0: vec![1.0, 0.0, 0.0],
            w: vec![1.0, 0.5, -0.2],
            t1: 0.01,
        },
    ]
}

fn weighted(w: &[f64], y: &[f64]) -> f64 {
    w.iter().zip(y).map(|(wi, yi)| wi * yi).sum()
}

// ---------------------------------------------------------------------------
// Fixed tape: FD on the discrete map itself, explicit and implicit methods.
// ---------------------------------------------------------------------------

fn fixed_loss(sys: &dyn OdeSystem, y0: &[f64], w: &[f64], t1: f64, n: usize, m: MethodId) -> f64 {
    let y0b = BatchVec::from_rows(&[y0.to_vec()]);
    let tape = rk_forward_tape(sys, &y0b, 0.0, t1 / n as f64, n, m);
    weighted(w, tape.y_final().row(0))
}

#[test]
fn fixed_tape_gradients_match_discrete_fd() {
    // DIRK stages on the explicit side too: DOPRI5 run at fixed step.
    for m in [MethodId::DOPRI5, MethodId::TRBDF2, MethodId::KVAERNO43] {
        for c in cases() {
            let n = 60;
            let sys = (c.make)(c.param.unwrap_or(0.0));
            let y0b = BatchVec::from_rows(&[c.y0.clone()]);
            let tape = rk_forward_tape(sys.as_ref(), &y0b, 0.0, c.t1 / n as f64, n, m);
            let seed = BatchVec::from_rows(&[c.w.clone()]);
            let (dy0, dp) = rk_backward(sys.as_ref(), &tape, &seed);
            // FD w.r.t. each initial-condition component.
            for d in 0..c.y0.len() {
                let h = 1e-5 * (1.0 + c.y0[d].abs());
                let mut yp = c.y0.clone();
                yp[d] += h;
                let mut ym = c.y0.clone();
                ym[d] -= h;
                let fd = (fixed_loss(sys.as_ref(), &yp, &c.w, c.t1, n, m)
                    - fixed_loss(sys.as_ref(), &ym, &c.w, c.t1, n, m))
                    / (2.0 * h);
                let got = dy0.row(0)[d];
                assert!(
                    (got - fd).abs() < 2e-4 * (1.0 + fd.abs()),
                    "{} {m:?} dy0[{d}]: {got} vs fd {fd}",
                    c.name,
                );
            }
            // FD w.r.t. the scalar parameter, where the case has one.
            if let Some(p) = c.param {
                let h = 1e-5 * (1.0 + p.abs());
                let sp = (c.make)(p + h);
                let sm = (c.make)(p - h);
                let fd = (fixed_loss(sp.as_ref(), &c.y0, &c.w, c.t1, n, m)
                    - fixed_loss(sm.as_ref(), &c.y0, &c.w, c.t1, n, m))
                    / (2.0 * h);
                assert!(
                    (dp[0] - fd).abs() < 2e-4 * (1.0 + fd.abs()),
                    "{} {m:?} dθ: {} vs fd {fd}",
                    c.name,
                    dp[0]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Adaptive tape: FD on the adaptive forward loss at tight tolerances.
// ---------------------------------------------------------------------------

fn adaptive_loss(sys: &dyn OdeSystem, y0: &[f64], w: &[f64], t1: f64, opts: &SolveOptions) -> f64 {
    let y0b = BatchVec::from_rows(&[y0.to_vec()]);
    let (sol, tape) = rk_forward_tape_adaptive(sys, &y0b, 0.0, t1, opts);
    assert!(sol.all_success());
    weighted(w, tape.y_final().row(0))
}

#[test]
fn adaptive_tape_gradients_match_fd() {
    // Explicit and implicit adaptive solves; the implicit replay
    // re-solves every DIRK stage through Newton.
    let combos: Vec<(MethodId, &str)> = vec![
        (MethodId::DOPRI5, "linear-decay"),
        (MethodId::DOPRI5, "vdp"),
        (MethodId::DOPRI5, "robertson"),
        (MethodId::TRBDF2, "vdp"),
        (MethodId::KVAERNO43, "vdp"),
        (MethodId::TRBDF2, "robertson"),
    ];
    for (m, name) in combos {
        let c = cases().into_iter().find(|c| c.name == name).unwrap();
        let sys = (c.make)(c.param.unwrap_or(0.0));
        let opts = SolveOptions::new(m).with_tols(1e-9, 1e-9).with_max_steps(200_000);
        let y0b = BatchVec::from_rows(&[c.y0.clone()]);
        let (sol, tape) = rk_forward_tape_adaptive(sys.as_ref(), &y0b, 0.0, c.t1, &opts);
        assert!(sol.all_success(), "{name} {m:?} forward failed");
        let seed = BatchVec::from_rows(&[c.w.clone()]);
        let (dy0, dp) = rk_backward_adaptive(sys.as_ref(), &tape, &seed);
        for d in 0..c.y0.len() {
            let h = 1e-5 * (1.0 + c.y0[d].abs());
            let mut yp = c.y0.clone();
            yp[d] += h;
            let mut ym = c.y0.clone();
            ym[d] -= h;
            let fd = (adaptive_loss(sys.as_ref(), &yp, &c.w, c.t1, &opts)
                - adaptive_loss(sys.as_ref(), &ym, &c.w, c.t1, &opts))
                / (2.0 * h);
            let got = dy0.row(0)[d];
            assert!(
                (got - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "{name} {m:?} dy0[{d}]: {got} vs fd {fd}"
            );
        }
        if let Some(p) = c.param {
            let h = 1e-5 * (1.0 + p.abs());
            let fd = (adaptive_loss((c.make)(p + h).as_ref(), &c.y0, &c.w, c.t1, &opts)
                - adaptive_loss((c.make)(p - h).as_ref(), &c.y0, &c.w, c.t1, &opts))
                / (2.0 * h);
            assert!(
                (dp[0] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "{name} {m:?} dθ: {} vs fd {fd}",
                dp[0]
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Backsolve: continuous adjoint vs FD of a high-accuracy reference solve.
// ---------------------------------------------------------------------------

fn reference_loss(sys: &dyn OdeSystem, y0: &[f64], w: &[f64], t1: f64) -> f64 {
    let y0b = BatchVec::from_rows(&[y0.to_vec()]);
    let grid = TimeGrid::linspace_shared(1, 0.0, t1, 2);
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-12, 1e-12).with_max_steps(500_000);
    let sol = solve_ivp_parallel(sys, &y0b, &grid, &opts);
    assert!(sol.all_success());
    weighted(w, sol.y_final(0))
}

fn backsolve_grad(
    sys: &dyn OdeSystem,
    y0: &[f64],
    w: &[f64],
    t1: f64,
    checkpoints: usize,
) -> (Vec<f64>, Vec<f64>) {
    let y0b = BatchVec::from_rows(&[y0.to_vec()]);
    let grid = TimeGrid::linspace_shared(1, 0.0, t1, 2);
    let fw = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10).with_max_steps(500_000);
    let sol = solve_ivp_parallel(sys, &y0b, &grid, &fw);
    assert!(sol.all_success());
    let y1 = BatchVec::from_rows(&[sol.y_final(0).to_vec()]);
    let dl = BatchVec::from_rows(&[w.to_vec()]);
    let opts = AdjointOptions::new(fw).with_checkpoints(checkpoints);
    let res = backsolve_adjoint_parallel(sys, &y0b, &y1, &dl, &[0.0], &[t1], &opts);
    assert!(res.status.iter().all(|s| *s == Status::Success));
    (res.dl_dy0.row(0).to_vec(), res.dl_dparams)
}

#[test]
fn backsolve_gradients_match_fd() {
    // Robertson's stiff mode amplifies reversal error as e^{10⁴·s}, so
    // the backsolve leg uses a one-relaxation-time horizon and enough
    // checkpoints to keep each segment's amplification mild — exactly
    // the regime checkpointing exists for.
    let combos: Vec<(&str, f64, usize)> =
        vec![("linear-decay", 1.5, 1), ("vdp", 1.5, 1), ("vdp", 1.5, 4), ("robertson", 5e-4, 5)];
    for (name, t1, k) in combos {
        let c = cases().into_iter().find(|c| c.name == name).unwrap();
        let sys = (c.make)(c.param.unwrap_or(0.0));
        let (dy0, dp) = backsolve_grad(sys.as_ref(), &c.y0, &c.w, t1, k);
        for d in 0..c.y0.len() {
            let h = 1e-5 * (1.0 + c.y0[d].abs());
            let mut yp = c.y0.clone();
            yp[d] += h;
            let mut ym = c.y0.clone();
            ym[d] -= h;
            let fd = (reference_loss(sys.as_ref(), &yp, &c.w, t1)
                - reference_loss(sys.as_ref(), &ym, &c.w, t1))
                / (2.0 * h);
            assert!(
                (dy0[d] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "{name} k={k} dy0[{d}]: {} vs fd {fd}",
                dy0[d]
            );
        }
        if let Some(p) = c.param {
            let h = 1e-5 * (1.0 + p.abs());
            let fd = (reference_loss((c.make)(p + h).as_ref(), &c.y0, &c.w, t1)
                - reference_loss((c.make)(p - h).as_ref(), &c.y0, &c.w, t1))
                / (2.0 * h);
            assert!(
                (dp[0] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "{name} k={k} dθ: {} vs fd {fd}",
                dp[0]
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Cross-mode agreement: three estimators of the same continuous gradient.
// ---------------------------------------------------------------------------

#[test]
fn all_three_modes_agree_on_vdp() {
    let c = cases().into_iter().find(|c| c.name == "vdp").unwrap();
    let sys = (c.make)(c.param.unwrap());
    let y0b = BatchVec::from_rows(&[c.y0.clone()]);
    let seed = BatchVec::from_rows(&[c.w.clone()]);

    let n = 400;
    let tape = rk_forward_tape(sys.as_ref(), &y0b, 0.0, c.t1 / n as f64, n, MethodId::DOPRI5);
    let (fx_dy0, fx_dp) = rk_backward(sys.as_ref(), &tape, &seed);

    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10);
    let (sol, atape) = rk_forward_tape_adaptive(sys.as_ref(), &y0b, 0.0, c.t1, &opts);
    assert!(sol.all_success());
    let (ad_dy0, ad_dp) = rk_backward_adaptive(sys.as_ref(), &atape, &seed);

    let (bs_dy0, bs_dp) = backsolve_grad(sys.as_ref(), &c.y0, &c.w, c.t1, 2);

    for d in 0..c.y0.len() {
        let a = fx_dy0.row(0)[d];
        let b = ad_dy0.row(0)[d];
        let s = bs_dy0[d];
        assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "fixed vs adaptive dy0[{d}]: {a} vs {b}");
        assert!((a - s).abs() < 1e-4 * (1.0 + a.abs()), "fixed vs backsolve dy0[{d}]: {a} vs {s}");
    }
    assert!((fx_dp[0] - ad_dp[0]).abs() < 1e-4 * (1.0 + fx_dp[0].abs()));
    assert!((fx_dp[0] - bs_dp[0]).abs() < 1e-4 * (1.0 + fx_dp[0].abs()));
}

// ---------------------------------------------------------------------------
// Determinism: bitwise-identical gradients across exec configurations.
// ---------------------------------------------------------------------------

fn grad_bits(dy0: &BatchVec, dp: &[f64]) -> Vec<u64> {
    let mut bits = Vec::new();
    for i in 0..dy0.batch() {
        bits.extend(dy0.row(i).iter().map(|v| v.to_bits()));
    }
    bits.extend(dp.iter().map(|v| v.to_bits()));
    bits
}

#[test]
fn gradients_bitwise_identical_across_exec_configs() {
    let b = 6;
    let sys = VdP::new(vec![0.6, 1.4, 2.2, 0.9, 3.0, 1.1]);
    let y0 = BatchVec::broadcast(&[1.5, 0.0], b);
    let seed = BatchVec::broadcast(&[1.0, -0.5], b);
    let t1 = 1.2;
    let grid = TimeGrid::linspace_shared(b, 0.0, t1, 2);
    let base = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8).with_trace();

    let mut tape_ref: Option<Vec<u64>> = None;
    let mut back_ref: Option<Vec<u64>> = None;
    for kind in [PoolKind::Serial, PoolKind::Scoped, PoolKind::Persistent] {
        for threads in [1usize, 3] {
            for layout in [Layout::RowMajor, Layout::DimMajor] {
                let label = format!("{} threads={threads} {}", kind.name(), layout.name());
                let opts =
                    base.clone().with_pool(kind).with_threads(threads).with_layout(layout);

                // Adaptive tape: pooled, traced forward → serial replay.
                let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
                assert!(sol.all_success(), "{label}");
                let tape = replay_tape(&sys, &y0, &sol, MethodId::DOPRI5);
                let (dy0, dp) = rk_backward_adaptive(&sys, &tape, &seed);
                let bits = grad_bits(&dy0, &dp);
                match &tape_ref {
                    None => tape_ref = Some(bits),
                    Some(r) => assert_eq!(r, &bits, "adaptive-tape grads differ: {label}"),
                }

                // Backsolve: pooled forward for y1, adjoint under the
                // same varied layout.
                let mut y1 = BatchVec::zeros(b, 2);
                for i in 0..b {
                    y1.row_mut(i).copy_from_slice(sol.y_final(i));
                }
                let adj = AdjointOptions::new(opts.clone()).with_checkpoints(2);
                let res = backsolve_adjoint_parallel(
                    &sys,
                    &y0,
                    &y1,
                    &seed,
                    &vec![0.0; b],
                    &vec![t1; b],
                    &adj,
                );
                let bits = grad_bits(&res.dl_dy0, &res.dl_dparams);
                match &back_ref {
                    None => back_ref = Some(bits),
                    Some(r) => assert_eq!(r, &bits, "backsolve grads differ: {label}"),
                }
            }
        }
    }
}
