//! The lane-kernel / layout contract: the lane-blocked stage kernels
//! (`rode::solver::kernels`) and the dim-major (SoA) workspace layout
//! are **bitwise-identical** to the frozen mask-based reference loop
//! (`rode::solver::reference`, which still drives the historical
//! row-major whole-batch path) across odd dims, FSAL and non-FSAL
//! methods, fixed-step methods, compaction thresholds, pool kinds and
//! the joint loop. Plus direct per-element parity of every lane kernel
//! against the preserved scalar bodies in `kernels::scalar`.

use rode::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
use rode::nn::Rng64;
use rode::prelude::*;
use rode::problems::ExponentialDecay;
use rode::solver::reference::solve_ivp_parallel_reference;
use rode::solver::{kernels, norm};
use rode::tensor::LaneStore;

/// Full bitwise equality of two solutions (NaN-safe via bit comparison).
fn assert_bitwise(a: &Solution, b: &Solution, label: &str) {
    assert_eq!(a.status, b.status, "{label}: status");
    assert_eq!(a.stats, b.stats, "{label}: stats");
    let (fa, fb) = (a.ys_flat(), b.ys_flat());
    assert_eq!(fa.len(), fb.len(), "{label}: ys length");
    for (idx, (x, y)) in fa.iter().zip(fb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: ys[{idx}] {x} vs {y}");
    }
    assert_eq!(a.trace, b.trace, "{label}: trace");
}

/// A heterogeneous decay batch at an arbitrary `dim`: per-instance rates
/// spread two orders of magnitude so rows finish at different times (the
/// regime where the active set, compaction and keep-alive paths all
/// fire).
fn workload(batch: usize, dim: usize, seed: u64) -> (ExponentialDecay, BatchVec, TimeGrid) {
    let mut rng = Rng64::new(seed);
    let rates: Vec<f64> = (0..batch).map(|_| rng.range(0.05, 5.0)).collect();
    let sys = ExponentialDecay::new(rates, dim);
    let y0 = BatchVec::from_rows(
        &(0..batch).map(|_| (0..dim).map(|_| rng.range(-2.0, 2.0)).collect()).collect::<Vec<_>>(),
    );
    let grid = TimeGrid::linspace_shared(batch, 0.0, 3.0, 7);
    (sys, y0, grid)
}

/// Both layouts, FSAL and non-FSAL adaptive methods, both eval modes,
/// with and without eager compaction, across odd dims: all bitwise equal
/// to the frozen reference loop.
#[test]
fn both_layouts_match_reference_across_odd_dims() {
    for &dim in &[1usize, 3, 5, 7, 13] {
        let (sys, y0, grid) = workload(6, dim, dim as u64);
        for m in [MethodId::DOPRI5, MethodId::CASHKARP45] {
            let base =
                SolveOptions::new(m).with_tols(1e-6, 1e-6).with_max_steps(100_000).with_trace();
            for eval_inactive in [true, false] {
                let mut opts = base.clone();
                opts.eval_inactive = eval_inactive;
                let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &opts);
                assert!(reference.all_success(), "{m:?} dim={dim}");
                for layout in [Layout::RowMajor, Layout::DimMajor] {
                    for threshold in [0.0, 1.0] {
                        let copts = opts.clone().with_layout(layout).with_compaction(threshold);
                        let got = solve_ivp_parallel(&sys, &y0, &grid, &copts);
                        assert_bitwise(
                            &reference,
                            &got,
                            &format!(
                                "{m:?} dim={dim} {} eval_inactive={eval_inactive} \
                                 threshold={threshold}",
                                layout.name()
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// Fixed-step methods (no controller, no embedded error — the
/// solution-only combine path) in both layouts.
#[test]
fn fixed_step_layout_parity() {
    for &dim in &[3usize, 13] {
        let (sys, y0, grid) = workload(4, dim, 77 + dim as u64);
        let base = SolveOptions::new(MethodId::RK4).with_fixed_dt(5e-3).with_max_steps(20_000);
        let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &base);
        for layout in [Layout::RowMajor, Layout::DimMajor] {
            let got = solve_ivp_parallel(&sys, &y0, &grid, &base.clone().with_layout(layout));
            assert_bitwise(&reference, &got, &format!("rk4 dim={dim} {}", layout.name()));
        }
    }
}

/// The pooled parallel path shards dim-major workspaces per worker; the
/// merged result must still equal the serial reference bitwise for both
/// pool kinds.
#[test]
fn pooled_layouts_match_reference() {
    let (sys, y0, grid) = workload(10, 5, 11);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(100_000)
        .with_trace();
    let reference = solve_ivp_parallel_reference(&sys, &y0, &grid, &base);
    for layout in [Layout::RowMajor, Layout::DimMajor] {
        for kind in [PoolKind::Scoped, PoolKind::Persistent] {
            let opts = base
                .clone()
                .with_layout(layout)
                .with_threads(3)
                .with_pool(kind)
                .with_compaction(0.75);
            let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(&reference, &got, &format!("pooled {} {}", kind.name(), layout.name()));
        }
    }
}

/// The joint loop: dim-major must match row-major bitwise, serially and
/// through both pooled executors (which drive the row-range kernel
/// whatever the layout — legal only because the layouts are
/// element-exact).
#[test]
fn joint_layout_parity_serial_and_pooled() {
    for &dim in &[1usize, 3, 7, 13] {
        let (sys, y0, grid) = workload(6, dim, 200 + dim as u64);
        let base =
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(100_000);
        let row = solve_ivp_joint(&sys, &y0, &grid, &base);
        assert!(row.all_success(), "dim={dim}");
        let dm = solve_ivp_joint(&sys, &y0, &grid, &base.clone().with_layout(Layout::DimMajor));
        assert_bitwise(&row, &dm, &format!("joint dim={dim} dim_major"));
        for kind in [PoolKind::Scoped, PoolKind::Persistent] {
            let opts = base
                .clone()
                .with_layout(Layout::DimMajor)
                .with_threads(2)
                .with_pool(kind);
            let pooled = solve_ivp_joint_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(&row, &pooled, &format!("joint pooled {} dim={dim}", kind.name()));
        }
    }
}

/// Non-FSAL joint loop in both layouts (exercises the dim-major k[0]
/// reload after the end-slope refresh).
#[test]
fn joint_non_fsal_layout_parity() {
    let (sys, y0, grid) = workload(4, 5, 31);
    let base =
        SolveOptions::new(MethodId::FEHLBERG45).with_tols(1e-6, 1e-6).with_max_steps(100_000);
    let row = solve_ivp_joint(&sys, &y0, &grid, &base);
    let dm = solve_ivp_joint(&sys, &y0, &grid, &base.clone().with_layout(Layout::DimMajor));
    assert_bitwise(&row, &dm, "joint fehlberg45 dim_major");
}

/// Direct per-element parity of the lane-blocked kernels against the
/// preserved scalar bodies, on solver-shaped data (dopri5 coefficient
/// counts) across odd dims.
#[test]
fn lane_kernels_bitwise_equal_scalar_on_solver_shapes() {
    let ct = rode::solver::step::CompiledTableau::cached(MethodId::DOPRI5);
    let mut rng = Rng64::new(5);
    for &dim in &[1usize, 3, 5, 7, 13] {
        let y: Vec<f64> = (0..dim).map(|_| rng.range(-2.0, 2.0)).collect();
        let kdata: Vec<Vec<f64>> =
            (0..7).map(|_| (0..dim).map(|_| rng.range(-3.0, 3.0)).collect()).collect();
        let kr: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
        let h = 0.013;

        // Stage rows with dopri5's real sparsity patterns.
        for s in 1..7 {
            let nz = &ct.a_nz[s];
            let w: Vec<f64> = nz.iter().map(|&(_, w)| w).collect();
            let k: Vec<&[f64]> = nz.iter().map(|&(j, _)| kr[j]).collect();
            let mut lane = vec![0.0; dim];
            let mut scal = vec![0.0; dim];
            kernels::stage_row(&mut lane, &y, h, &w, &k);
            kernels::scalar::stage_row(&mut scal, &y, h, &w, &k);
            for d in 0..dim {
                assert_eq!(lane[d].to_bits(), scal[d].to_bits(), "stage s={s} dim={dim} d={d}");
            }
        }

        // The fused combine pair vs two scalar passes with dopri5's b/b_err.
        let bw: Vec<f64> = ct.b_nz.iter().map(|&(_, w)| w).collect();
        let bk: Vec<&[f64]> = ct.b_nz.iter().map(|&(j, _)| kr[j]).collect();
        let ew: Vec<f64> = ct.berr_nz.iter().map(|&(_, w)| w).collect();
        let ek: Vec<&[f64]> = ct.berr_nz.iter().map(|&(j, _)| kr[j]).collect();
        let mut yn = vec![0.0; dim];
        let mut er = vec![0.0; dim];
        kernels::combine_pair_row(&mut yn, &mut er, &y, h, &bw, &bk, &ew, &ek);
        let mut yn_s = vec![0.0; dim];
        let mut er_s = vec![0.0; dim];
        kernels::scalar::combine_row(&mut yn_s, Some(&y), h, &bw, &bk);
        kernels::scalar::combine_row(&mut er_s, None, h, &ew, &ek);
        for d in 0..dim {
            assert_eq!(yn[d].to_bits(), yn_s[d].to_bits(), "y_new dim={dim} d={d}");
            assert_eq!(er[d].to_bits(), er_s[d].to_bits(), "err dim={dim} d={d}");
        }
    }
}

/// The implicit method is layout-blind by construction (per-row Newton
/// solves have no lane passes), but the contract is the same as for the
/// explicit kernels: `dim_major`, compaction, `eval_inactive` and both
/// pooled paths must all be bitwise-identical to the serial row-major
/// solve — including the Newton counters in `Stats`. (The frozen
/// reference loop predates implicit methods, so the serial active-set
/// solve is the oracle here.)
#[test]
fn implicit_layouts_compaction_and_pools_bitwise() {
    for &dim in &[1usize, 3, 5] {
        let (sys, y0, grid) = workload(6, dim, 400 + dim as u64);
        let base = SolveOptions::new(MethodId::TRBDF2)
            .with_tols(1e-7, 1e-6)
            .with_max_steps(100_000)
            .with_trace();
        let serial = solve_ivp_parallel(&sys, &y0, &grid, &base);
        assert!(serial.all_success(), "dim={dim}");
        for eval_inactive in [true, false] {
            for layout in [Layout::RowMajor, Layout::DimMajor] {
                for threshold in [0.0, 1.0] {
                    let mut opts = base.clone().with_layout(layout).with_compaction(threshold);
                    opts.eval_inactive = eval_inactive;
                    let got = solve_ivp_parallel(&sys, &y0, &grid, &opts);
                    assert_bitwise(
                        &serial,
                        &got,
                        &format!(
                            "implicit dim={dim} {} eval_inactive={eval_inactive} \
                             threshold={threshold}",
                            layout.name()
                        ),
                    );
                }
            }
        }
        for kind in [PoolKind::Scoped, PoolKind::Persistent] {
            let opts = base
                .clone()
                .with_layout(Layout::DimMajor)
                .with_threads(3)
                .with_pool(kind)
                .with_compaction(0.75);
            let got = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            assert_bitwise(&serial, &got, &format!("implicit pooled {} dim={dim}", kind.name()));
        }
        // Joint, both layouts, serial and pooled.
        let jrow = solve_ivp_joint(&sys, &y0, &grid, &base);
        let jdm = solve_ivp_joint(&sys, &y0, &grid, &base.clone().with_layout(Layout::DimMajor));
        assert_bitwise(&jrow, &jdm, &format!("implicit joint dim={dim} dim_major"));
        let jp = solve_ivp_joint_pooled(
            &sys,
            &y0,
            &grid,
            &base.clone().with_threads(2).with_pool(PoolKind::Persistent),
        );
        assert_bitwise(&jrow, &jp, &format!("implicit joint pooled dim={dim}"));
    }
}

/// The error-norm contracts under the lane tree: the RMS norm is still
/// literally `sqrt(sumsq / len)` bitwise, short rows reduce exactly like
/// the historical sequential sum, and a lane round-trip through the SoA
/// store never changes bits.
#[test]
fn sumsq_contracts_hold() {
    let mut rng = Rng64::new(9);
    for &dim in &[1usize, 3, 5, 7, 13, 16, 64] {
        let e: Vec<f64> = (0..dim).map(|_| rng.range(-1e-5, 1e-5)).collect();
        let a: Vec<f64> = (0..dim).map(|_| rng.range(-2.0, 2.0)).collect();
        let b: Vec<f64> = (0..dim).map(|_| rng.range(-2.0, 2.0)).collect();
        let s = norm::scaled_sumsq(&e, &a, &b, 1e-8, 1e-5);
        let n = norm::scaled_norm(norm::NormKind::Rms, &e, &a, &b, 1e-8, 1e-5);
        assert_eq!(n.to_bits(), (s / dim as f64).sqrt().to_bits(), "rms contract dim={dim}");
        if dim < 4 {
            let seq = kernels::scalar::scaled_sumsq(&e, &a, &b, 1e-8, 1e-5);
            assert_eq!(s.to_bits(), seq.to_bits(), "short-row degeneration dim={dim}");
        }
    }

    // SoA round-trip exactness on a batch of rows.
    let batch = 9;
    let dim = 5;
    let mut flat = Vec::new();
    for _ in 0..batch * dim {
        flat.push(rng.range(-3.0, 3.0));
    }
    let mut ls = LaneStore::new(batch, dim);
    ls.load(&flat, batch);
    let mut back = vec![0.0; batch * dim];
    ls.store_rows(&mut back, batch);
    for (i, (x, y)) in flat.iter().zip(&back).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "lane round-trip at {i}");
    }
}
