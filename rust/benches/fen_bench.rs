//! Bench: Table 4 — the FEN (graph-ODE) forward-pass benchmark.
//!
//! Run with `cargo bench --bench fen_bench`.

use rode::experiments::{fen_table4, FenT4Config};

fn main() {
    println!("=== Table 4: FEN stand-in (batch 8, 24-node mesh, 10 eval pts) ===");
    let rows = fen_table4(&FenT4Config::default());
    println!(
        "{:<28} {:>20} {:>18} {:>18} {:>7} {:>8}",
        "engine", "loop (ms/step)", "total/step (ms)", "model/step (ms)", "steps", "MAE"
    );
    for r in &rows {
        println!(
            "{:<28} {:>20} {:>18} {:>18} {:>7.1} {:>8.4}",
            r.engine,
            r.loop_time_ms.format_ms(),
            r.total_per_step_ms.format_ms(),
            r.model_per_step_ms.format_ms(),
            r.steps.mean,
            r.mae,
        );
    }
    println!(
        "\npaper shape: loop time is a small fraction of total/step once the\n\
         model is real (learned dynamics dominate); engines agree on MAE and steps."
    );
}
