//! Bench: Table 2 (VdP) / Table 3 — loop time per engine on the paper's
//! exact workload (256 VdP problems, μ=2, one cycle, dopri5, tol 1e-5,
//! 200 eval points), plus the §4.1 step-ratio series.
//!
//! Run with `cargo bench --bench vdp_loop_time`.

use rode::experiments::{sec41_steps, vdp_table3, VdpT3Config, SIM_LAUNCH_MS};

fn main() {
    println!("=== Table 3: VdP loop time (batch 256, mu=2, 200 eval pts, dopri5) ===");
    let cfg = VdpT3Config::default();
    let rows = vdp_table3(&cfg);
    println!(
        "{:<28} {:>22} {:>14} {:>7} {:>14} {:>12}",
        "engine", "loop time (ms/step)", "total (ms)", "steps", "launches/step", "sim (ms/st)"
    );
    for r in &rows {
        println!(
            "{:<28} {:>22} {:>14.3} {:>7} {:>14.1} {:>12.3}",
            r.engine,
            r.loop_time_ms.format_ms(),
            r.total_ms.mean,
            r.steps,
            r.launches_per_step,
            r.launches_per_step * SIM_LAUNCH_MS,
        );
    }

    println!("\n=== Sec 4.1: joint-batching step blow-up (mu=25) ===");
    println!("{:>6} {:>12} {:>14} {:>7}", "batch", "joint", "parallel-max", "ratio");
    for p in sec41_steps(25.0, 1e-5, &[1, 2, 4, 8, 16, 32, 64, 128]) {
        println!(
            "{:>6} {:>12} {:>14} {:>7.2}",
            p.batch, p.joint_steps, p.parallel_max_steps, p.ratio
        );
    }
}
