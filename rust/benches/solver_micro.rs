//! Micro-benchmarks + ablations of the solver hot path:
//!
//! - `rk_attempt` cost across batch/dim (the per-step kernel),
//! - error-norm and interpolation kernels,
//! - ablations the paper calls out: FSAL reuse, Horner vs naive
//!   polynomial evaluation, zero-coefficient skipping, and the rode
//!   extension `eval_inactive=false`.
//!
//! Run with `cargo bench --bench solver_micro`.

use rode::bench::{time_repeats, Summary};
use rode::prelude::*;
use rode::problems::VdP;
use rode::solver::interp;
use rode::solver::norm::{scaled_norm, NormKind};
use rode::solver::step::{rk_attempt, CompiledTableau, RkWorkspace};
use rode::tensor::BatchVec;

fn summary_line(name: &str, xs: &[f64], per: f64, unit: &str) {
    let s = Summary::from_samples(xs);
    println!(
        "{name:<46} {:>12.3} ± {:>8.3} µs{}",
        s.mean * 1e3 / per,
        s.std * 1e3 / per,
        if unit.is_empty() { String::new() } else { format!("  (per {unit})") }
    );
}

fn bench_rk_attempt() {
    println!("--- rk_attempt (dopri5, one batched step) ---");
    for &(batch, dim) in &[(16usize, 2usize), (256, 2), (1024, 2), (256, 16), (64, 128)] {
        let sys = VdP::uniform(batch, 2.0);
        let dim_eff = 2.min(dim);
        let _ = dim_eff;
        // VdP has dim 2; emulate larger dims with ExponentialDecay.
        let run = |reps: usize| -> Vec<f64> {
            if dim == 2 {
                let ct = CompiledTableau::new(Method::Dopri5.tableau());
                let mut ws = RkWorkspace::new(7, batch, 2);
                let y = BatchVec::broadcast(&[2.0, 0.0], batch);
                let t = vec![0.0; batch];
                let dt = vec![0.01; batch];
                let k0 = vec![false; batch];
                time_repeats(3, reps, || {
                    rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws, &k0, None, true);
                })
            } else {
                let sys = rode::problems::ExponentialDecay::new(vec![1.0], dim);
                let ct = CompiledTableau::new(Method::Dopri5.tableau());
                let mut ws = RkWorkspace::new(7, batch, dim);
                let y = BatchVec::zeros(batch, dim);
                let t = vec![0.0; batch];
                let dt = vec![0.01; batch];
                let k0 = vec![false; batch];
                time_repeats(3, reps, || {
                    rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws, &k0, None, true);
                })
            }
        };
        summary_line(&format!("rk_attempt b={batch} d={dim}"), &run(50), 1.0, "");
    }
}

fn bench_norm_interp() {
    println!("--- fused error norm + Horner interpolation (b=256, d=16) ---");
    let (b, d) = (256, 16);
    let err = vec![1e-6; b * d];
    let y0 = vec![1.0; b * d];
    let y1 = vec![1.1; b * d];
    let xs = time_repeats(3, 200, || {
        for i in 0..b {
            std::hint::black_box(scaled_norm(
                NormKind::Rms,
                &err[i * d..(i + 1) * d],
                &y0[i * d..(i + 1) * d],
                &y1[i * d..(i + 1) * d],
                1e-6,
                1e-5,
            ));
        }
    });
    summary_line("scaled_norm batch", &xs, 1.0, "");

    let kdata: Vec<Vec<f64>> = (0..7).map(|s| vec![0.1 * s as f64; d]).collect();
    let k: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
    let mut coeffs = vec![0.0; interp::DOPRI5_NCOEFF * d];
    let mut out = vec![0.0; d];
    let xs = time_repeats(3, 200, || {
        for i in 0..b {
            let _ = i;
            interp::dopri5_coeffs(0.1, &y0[..d], &y1[..d], &k, &mut coeffs);
            for e in 0..4 {
                interp::dopri5_eval(e as f64 / 4.0, &coeffs, &mut out);
                std::hint::black_box(&out);
            }
        }
    });
    summary_line("dopri5 coeffs + 4 Horner evals (batch)", &xs, 1.0, "");
}

fn bench_ablations() {
    println!("--- ablations (batch 256 VdP, one cycle, tol 1e-5) ---");
    let batch = 256;
    let sys = VdP::uniform(batch, 2.0);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], batch);
    let t1 = VdP::approx_period(2.0);
    let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 200);

    // FSAL (dopri5/tsit5) vs non-FSAL (cashkarp45) at equal order: count
    // dynamics evaluations.
    for m in [Method::Dopri5, Method::Tsit5, Method::CashKarp45, Method::Fehlberg45] {
        let opts = SolveOptions::new(m).with_tols(1e-5, 1e-5).with_max_steps(100_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        println!(
            "{:<28} steps {:>5}  f_evals {:>6}  (evals/step {:.2})",
            format!("method {} (fsal={})", m.name(), m.tableau().fsal),
            sol.stats[0].n_steps,
            sol.stats[0].n_f_evals,
            sol.stats[0].n_f_evals as f64 / sol.stats[0].n_steps as f64
        );
    }

    // eval_inactive: torchode semantics (true) vs the rode extension.
    let mus: Vec<f64> = (0..batch).map(|i| 0.5 + 10.0 * (i as f64 / batch as f64)).collect();
    let sys_het = VdP::new(mus);
    for (label, opts) in [
        ("eval_inactive=true (torchode)", SolveOptions::new(Method::Dopri5).with_tols(1e-5, 1e-5)),
        (
            "eval_inactive=false (rode ext)",
            SolveOptions::new(Method::Dopri5).with_tols(1e-5, 1e-5).skip_inactive(),
        ),
    ] {
        let xs = time_repeats(1, 5, || {
            let sol = solve_ivp_parallel(&sys_het, &y0, &grid, &opts);
            assert!(sol.all_success());
        });
        summary_line(label, &xs, 1.0, "");
    }
}

fn main() {
    bench_rk_attempt();
    bench_norm_interp();
    bench_ablations();
}
