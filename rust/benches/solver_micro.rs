//! Micro-benchmarks + ablations of the solver hot path:
//!
//! - `rk_attempt` cost across batch/dim (the per-step kernel),
//! - error-norm and interpolation kernels,
//! - the stage-kernel **dim sweep**: lane-blocked (and dim-major) kernels
//!   vs the preserved scalar kernels across dim × batch, recorded into
//!   `BENCH_solver.json` (`speedup_vs_scalar`),
//! - ablations the paper calls out: FSAL reuse, Horner vs naive
//!   polynomial evaluation, zero-coefficient skipping, and the rode
//!   extension `eval_inactive=false`.
//!
//! Run with `cargo bench --bench solver_micro`, or pass section names to
//! run a subset (`attempt`, `norm`, `ablations`, `dimsweep`), e.g.
//! `cargo bench --bench solver_micro -- dimsweep`.

use rode::bench::{append_bench_json, time_repeats, BenchRecord, Summary};
use rode::nn::Rng64;
use rode::prelude::*;
use rode::problems::VdP;
use rode::solver::interp;
use rode::solver::kernels;
use rode::solver::norm::{self, scaled_norm, NormKind};
use rode::solver::step::{rk_attempt, CompiledTableau, RkWorkspace, MAX_STAGES};
use rode::tensor::{BatchVec, LaneStore};

fn summary_line(name: &str, xs: &[f64], per: f64, unit: &str) {
    let s = Summary::from_samples(xs);
    println!(
        "{name:<46} {:>12.3} ± {:>8.3} µs{}",
        s.mean * 1e3 / per,
        s.std * 1e3 / per,
        if unit.is_empty() { String::new() } else { format!("  (per {unit})") }
    );
}

fn bench_rk_attempt() {
    println!("--- rk_attempt (dopri5, one batched step) ---");
    for &(batch, dim) in &[(16usize, 2usize), (256, 2), (1024, 2), (256, 16), (64, 128)] {
        let sys = VdP::uniform(batch, 2.0);
        let dim_eff = 2.min(dim);
        let _ = dim_eff;
        // VdP has dim 2; emulate larger dims with ExponentialDecay.
        let run = |reps: usize| -> Vec<f64> {
            if dim == 2 {
                let ct = CompiledTableau::new(MethodId::DOPRI5.tableau());
                let mut ws = RkWorkspace::new(7, batch, 2);
                let y = BatchVec::broadcast(&[2.0, 0.0], batch);
                let t = vec![0.0; batch];
                let dt = vec![0.01; batch];
                let k0 = vec![false; batch];
                time_repeats(3, reps, || {
                    rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws, &k0, None, true);
                })
            } else {
                let sys = rode::problems::ExponentialDecay::new(vec![1.0], dim);
                let ct = CompiledTableau::new(MethodId::DOPRI5.tableau());
                let mut ws = RkWorkspace::new(7, batch, dim);
                let y = BatchVec::zeros(batch, dim);
                let t = vec![0.0; batch];
                let dt = vec![0.01; batch];
                let k0 = vec![false; batch];
                time_repeats(3, reps, || {
                    rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws, &k0, None, true);
                })
            }
        };
        summary_line(&format!("rk_attempt b={batch} d={dim}"), &run(50), 1.0, "");
    }
}

fn bench_norm_interp() {
    println!("--- fused error norm + Horner interpolation (b=256, d=16) ---");
    let (b, d) = (256, 16);
    let err = vec![1e-6; b * d];
    let y0 = vec![1.0; b * d];
    let y1 = vec![1.1; b * d];
    let xs = time_repeats(3, 200, || {
        for i in 0..b {
            std::hint::black_box(scaled_norm(
                NormKind::Rms,
                &err[i * d..(i + 1) * d],
                &y0[i * d..(i + 1) * d],
                &y1[i * d..(i + 1) * d],
                1e-6,
                1e-5,
            ));
        }
    });
    summary_line("scaled_norm batch", &xs, 1.0, "");

    let kdata: Vec<Vec<f64>> = (0..7).map(|s| vec![0.1 * s as f64; d]).collect();
    let k: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
    let mut coeffs = vec![0.0; interp::DOPRI5_NCOEFF * d];
    let mut out = vec![0.0; d];
    let xs = time_repeats(3, 200, || {
        for i in 0..b {
            let _ = i;
            interp::dopri5_coeffs(0.1, &y0[..d], &y1[..d], &k, &mut coeffs);
            for e in 0..4 {
                interp::dopri5_eval(e as f64 / 4.0, &coeffs, &mut out);
                std::hint::black_box(&out);
            }
        }
    });
    summary_line("dopri5 coeffs + 4 Horner evals (batch)", &xs, 1.0, "");
}

fn bench_ablations() {
    println!("--- ablations (batch 256 VdP, one cycle, tol 1e-5) ---");
    let batch = 256;
    let sys = VdP::uniform(batch, 2.0);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], batch);
    let t1 = VdP::approx_period(2.0);
    let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 200);

    // FSAL (dopri5/tsit5) vs non-FSAL (cashkarp45) at equal order: count
    // dynamics evaluations.
    for m in [MethodId::DOPRI5, MethodId::TSIT5, MethodId::CASHKARP45, MethodId::FEHLBERG45] {
        let opts = SolveOptions::new(m).with_tols(1e-5, 1e-5).with_max_steps(100_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        println!(
            "{:<28} steps {:>5}  f_evals {:>6}  (evals/step {:.2})",
            format!("method {} (fsal={})", m.name(), m.tableau().fsal),
            sol.stats[0].n_steps,
            sol.stats[0].n_f_evals,
            sol.stats[0].n_f_evals as f64 / sol.stats[0].n_steps as f64
        );
    }

    // eval_inactive: torchode semantics (true) vs the rode extension.
    let mus: Vec<f64> = (0..batch).map(|i| 0.5 + 10.0 * (i as f64 / batch as f64)).collect();
    let sys_het = VdP::new(mus);
    for (label, opts) in [
        (
            "eval_inactive=true (torchode)",
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5),
        ),
        (
            "eval_inactive=false (rode ext)",
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5).skip_inactive(),
        ),
    ] {
        let xs = time_repeats(1, 5, || {
            let sol = solve_ivp_parallel(&sys_het, &y0, &grid, &opts);
            assert!(sol.all_success());
        });
        summary_line(label, &xs, 1.0, "");
    }
}

/// One attempt's worth of per-row arithmetic (dopri5 stage shapes, the
/// fused combine pair, the lane-tree error sum of squares) over the
/// lane-blocked kernels.
#[allow(clippy::too_many_arguments)]
fn attempt_arith_lane(
    stages: &[(Vec<f64>, Vec<usize>)],
    bw: &[f64],
    bj: &[usize],
    ew: &[f64],
    ej: &[usize],
    batch: usize,
    dim: usize,
    h: f64,
    y: &[f64],
    k: &[Vec<f64>],
    ytmp: &mut [f64],
    y_new: &mut [f64],
    err: &mut [f64],
) -> f64 {
    for (w, js) in stages {
        for r in 0..batch {
            let mut kr: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
            for (i, &j) in js.iter().enumerate() {
                kr[i] = &k[j][r * dim..(r + 1) * dim];
            }
            kernels::stage_row(
                &mut ytmp[r * dim..(r + 1) * dim],
                &y[r * dim..(r + 1) * dim],
                h,
                w,
                &kr[..js.len()],
            );
        }
    }
    for r in 0..batch {
        let mut bk: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
        for (i, &j) in bj.iter().enumerate() {
            bk[i] = &k[j][r * dim..(r + 1) * dim];
        }
        let mut ek: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
        for (i, &j) in ej.iter().enumerate() {
            ek[i] = &k[j][r * dim..(r + 1) * dim];
        }
        let (lo, hi) = (r * dim, (r + 1) * dim);
        let (ynr, er) = (&mut y_new[lo..hi], &mut err[lo..hi]);
        kernels::combine_pair_row(ynr, er, &y[lo..hi], h, bw, &bk[..bj.len()], ew, &ek[..ej.len()]);
    }
    let mut acc = 0.0;
    for r in 0..batch {
        let (lo, hi) = (r * dim, (r + 1) * dim);
        acc += norm::scaled_sumsq(&err[lo..hi], &y[lo..hi], &y_new[lo..hi], 1e-6, 1e-5);
    }
    acc
}

/// The same arithmetic over the preserved scalar kernels: straight-line
/// stage rows, two separate combine passes, sequential sum of squares.
#[allow(clippy::too_many_arguments)]
fn attempt_arith_scalar(
    stages: &[(Vec<f64>, Vec<usize>)],
    bw: &[f64],
    bj: &[usize],
    ew: &[f64],
    ej: &[usize],
    batch: usize,
    dim: usize,
    h: f64,
    y: &[f64],
    k: &[Vec<f64>],
    ytmp: &mut [f64],
    y_new: &mut [f64],
    err: &mut [f64],
) -> f64 {
    for (w, js) in stages {
        for r in 0..batch {
            let mut kr: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
            for (i, &j) in js.iter().enumerate() {
                kr[i] = &k[j][r * dim..(r + 1) * dim];
            }
            kernels::scalar::stage_row(
                &mut ytmp[r * dim..(r + 1) * dim],
                &y[r * dim..(r + 1) * dim],
                h,
                w,
                &kr[..js.len()],
            );
        }
    }
    for r in 0..batch {
        let mut bk: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
        for (i, &j) in bj.iter().enumerate() {
            bk[i] = &k[j][r * dim..(r + 1) * dim];
        }
        let (lo, hi) = (r * dim, (r + 1) * dim);
        kernels::scalar::combine_row(&mut y_new[lo..hi], Some(&y[lo..hi]), h, bw, &bk[..bj.len()]);
    }
    for r in 0..batch {
        let mut ek: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
        for (i, &j) in ej.iter().enumerate() {
            ek[i] = &k[j][r * dim..(r + 1) * dim];
        }
        let (lo, hi) = (r * dim, (r + 1) * dim);
        kernels::scalar::combine_row(&mut err[lo..hi], None, h, ew, &ek[..ej.len()]);
    }
    let mut acc = 0.0;
    for r in 0..batch {
        let (lo, hi) = (r * dim, (r + 1) * dim);
        acc += kernels::scalar::scaled_sumsq(&err[lo..hi], &y[lo..hi], &y_new[lo..hi], 1e-6, 1e-5);
    }
    acc
}

/// The stage-kernel dim sweep: per (dim, batch), one attempt's worth of
/// arithmetic through the scalar kernels, the lane-blocked kernels, and
/// the dim-major lanes (including the transposes the real dim-major
/// attempt pays at the dynamics boundary). Appends
/// `dimsweep-d{dim}-b{batch}` records (with `speedup_vs_scalar` and
/// `speedup_dm_vs_scalar`) to `BENCH_solver.json`.
fn bench_dim_sweep() {
    println!("--- stage-kernel dim sweep (dopri5 shapes, per attempt arithmetic) ---");
    let ct = CompiledTableau::cached(MethodId::DOPRI5);
    let stages: Vec<(Vec<f64>, Vec<usize>)> = (1..ct.tab.stages)
        .map(|s| {
            let nz = &ct.a_nz[s];
            (nz.iter().map(|&(_, w)| w).collect(), nz.iter().map(|&(j, _)| j).collect())
        })
        .collect();
    let bw: Vec<f64> = ct.b_nz.iter().map(|&(_, w)| w).collect();
    let bj: Vec<usize> = ct.b_nz.iter().map(|&(j, _)| j).collect();
    let ew: Vec<f64> = ct.berr_nz.iter().map(|&(_, w)| w).collect();
    let ej: Vec<usize> = ct.berr_nz.iter().map(|&(j, _)| j).collect();
    let h = 0.01;

    let mut records = Vec::new();
    for &dim in &[1usize, 4, 16, 64] {
        for &batch in &[64usize, 256, 1024] {
            let mut rng = Rng64::new(dim as u64 * 1000 + batch as u64);
            let n = batch * dim;
            let y: Vec<f64> = (0..n).map(|_| rng.range(-2.0, 2.0)).collect();
            let k: Vec<Vec<f64>> = (0..ct.tab.stages)
                .map(|_| (0..n).map(|_| rng.range(-3.0, 3.0)).collect())
                .collect();
            let mut ytmp = vec![0.0; n];
            let mut y_new = vec![0.0; n];
            let mut err = vec![0.0; n];
            let reps = (2_000_000 / n.max(1)).clamp(20, 2000);

            let xs_scalar = time_repeats(3, reps, || {
                let acc = attempt_arith_scalar(
                    &stages,
                    &bw,
                    &bj,
                    &ew,
                    &ej,
                    batch,
                    dim,
                    h,
                    &y,
                    &k,
                    &mut ytmp,
                    &mut y_new,
                    &mut err,
                );
                std::hint::black_box(acc);
            });
            let s_scalar = Summary::from_samples(&xs_scalar);

            let xs_lane = time_repeats(3, reps, || {
                let acc = attempt_arith_lane(
                    &stages,
                    &bw,
                    &bj,
                    &ew,
                    &ej,
                    batch,
                    dim,
                    h,
                    &y,
                    &k,
                    &mut ytmp,
                    &mut y_new,
                    &mut err,
                );
                std::hint::black_box(acc);
            });
            let s_lane = Summary::from_samples(&xs_lane);

            // Dim-major: lanes plus the transposes the real attempt pays
            // at the dynamics boundary (ytmp out, k[s] in, results out).
            let dt = vec![h; batch];
            let mut dm_y = LaneStore::new(batch, dim);
            let mut dm_k: Vec<LaneStore> =
                (0..ct.tab.stages).map(|_| LaneStore::new(batch, dim)).collect();
            let mut dm_ytmp = LaneStore::new(batch, dim);
            let mut dm_y_new = LaneStore::new(batch, dim);
            let mut dm_err = LaneStore::new(batch, dim);
            let xs_dm = time_repeats(3, reps, || {
                dm_y.load(&y, batch);
                dm_k[0].load(&k[0], batch);
                for (s, (w, js)) in stages.iter().enumerate() {
                    for d in 0..dim {
                        let mut kl: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
                        for (i, &j) in js.iter().enumerate() {
                            kl[i] = dm_k[j].lane(d);
                        }
                        // Split-borrow dance: ytmp lane out of dm_ytmp,
                        // slope lanes out of dm_k.
                        let y_lane = dm_y.lane(d);
                        kernels::stage_lanes(
                            &mut dm_ytmp.lane_mut(d)[..batch],
                            &y_lane[..batch],
                            &dt,
                            w,
                            &kl[..js.len()],
                        );
                    }
                    dm_ytmp.store_rows(&mut ytmp, batch);
                    dm_k[s + 1].load(&k[s + 1], batch);
                }
                for d in 0..dim {
                    let mut bk: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
                    for (i, &j) in bj.iter().enumerate() {
                        bk[i] = dm_k[j].lane(d);
                    }
                    let mut ek: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
                    for (i, &j) in ej.iter().enumerate() {
                        ek[i] = dm_k[j].lane(d);
                    }
                    let y_lane = dm_y.lane(d);
                    kernels::combine_pair_lanes(
                        &mut dm_y_new.lane_mut(d)[..batch],
                        &mut dm_err.lane_mut(d)[..batch],
                        &y_lane[..batch],
                        &dt,
                        &bw,
                        &bk[..bj.len()],
                        &ew,
                        &ek[..ej.len()],
                    );
                }
                dm_y_new.store_rows(&mut y_new, batch);
                dm_err.store_rows(&mut err, batch);
                let mut acc = 0.0;
                for r in 0..batch {
                    let (lo, hi) = (r * dim, (r + 1) * dim);
                    acc += norm::scaled_sumsq(&err[lo..hi], &y[lo..hi], &y_new[lo..hi], 1e-6, 1e-5);
                }
                std::hint::black_box(acc);
            });
            let s_dm = Summary::from_samples(&xs_dm);

            let speedup = s_scalar.mean / s_lane.mean;
            let speedup_dm = s_scalar.mean / s_dm.mean;
            println!(
                "d={dim:<3} b={batch:<5} scalar {:>9.4} ms  lane {:>9.4} ms (x{speedup:.2})  \
                 dim-major {:>9.4} ms (x{speedup_dm:.2})",
                s_scalar.mean,
                s_lane.mean,
                s_dm.mean
            );
            records.push(
                BenchRecord::new(&format!("dimsweep-d{dim}-b{batch}"), &s_lane)
                    .field("dim", dim as f64)
                    .field("batch", batch as f64)
                    .field("reps", reps as f64)
                    .field("scalar_ms", s_scalar.mean)
                    .field("dm_ms", s_dm.mean)
                    .field("speedup_vs_scalar", speedup)
                    .field("speedup_dm_vs_scalar", speedup_dm),
            );
        }
    }
    match append_bench_json("BENCH_solver.json", &records) {
        Ok(()) => println!("appended {} dimsweep records to BENCH_solver.json", records.len()),
        Err(e) => eprintln!("failed to write BENCH_solver.json: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a == name || a == "all");
    if want("attempt") {
        bench_rk_attempt();
    }
    if want("norm") {
        bench_norm_interp();
    }
    if want("dimsweep") {
        bench_dim_sweep();
    }
    if want("ablations") {
        bench_ablations();
    }
}
