//! Bench: Table 5 — the CNF adjoint benchmark (fw/bw loop times per
//! adjoint variant).
//!
//! Run with `cargo bench --bench cnf_bench`.

use rode::experiments::{cnf_table5, CnfT5Config};

fn main() {
    println!("=== Table 5: CNF stand-in (batch 16, d=2, MLP 32x32, adjoint) ===");
    let rows = cnf_table5(&CnfT5Config::default());
    println!(
        "{:<42} {:>18} {:>18} {:>9} {:>9} {:>10}",
        "variant", "fw loop (ms/st)", "bw loop (ms/st)", "fw steps", "bw steps", "bw state"
    );
    for r in &rows {
        println!(
            "{:<42} {:>18} {:>18} {:>9.0} {:>9.0} {:>10}",
            r.variant,
            r.fw_loop_ms.format_ms(),
            r.bw_loop_ms.format_ms(),
            r.fw_steps,
            r.bw_steps,
            r.bw_state_size,
        );
    }
    let per_inst = rows[0].bw_loop_ms.mean * rows[0].bw_steps;
    let joint = rows[1].bw_loop_ms.mean * rows[1].bw_steps;
    println!(
        "\nbackward totals: per-instance {:.1} ms vs joint {:.1} ms (x{:.1})\n\
         paper: torchode bw 58.1 ms vs torchode-joint 2.38 ms (x24) — the\n\
         per-instance adjoint pays for carrying the parameter block per instance.",
        per_inst,
        joint,
        per_inst / joint
    );
}
