//! Bench: the L3 coordinator — batcher throughput and end-to-end service
//! latency across batching configurations.
//!
//! Run with `cargo bench --bench coordinator_bench`.

use rode::bench::{threads_sweep, time_repeats, Summary};
use rode::coordinator::{
    Coordinator, DynamicBatcher, NativeEngine, ProblemSpec, ServiceConfig, SolveRequest,
};
use rode::exec::solve_ivp_parallel_pooled;
use rode::nn::Rng64;
use rode::solver::{Method, SolveOptions, TimeGrid};
use rode::tensor::BatchVec;
use std::time::{Duration, Instant};

fn req(rng: &mut Rng64, id: u64) -> SolveRequest {
    SolveRequest {
        id,
        problem: ProblemSpec::Vdp { mu: rng.range(0.5, 10.0) },
        y0: vec![rng.normal(), rng.normal()],
        t_eval: (0..20).map(|k| k as f64 * 0.25).collect(),
    }
}

fn bench_batcher() {
    println!("--- DynamicBatcher push throughput ---");
    let mut rng = Rng64::new(1);
    let reqs: Vec<SolveRequest> = (0..10_000).map(|i| req(&mut rng, i)).collect();
    let xs = time_repeats(2, 10, || {
        let mut b = DynamicBatcher::new(64, Duration::from_millis(1));
        let now = Instant::now();
        let mut flushed = 0;
        for r in reqs.iter().cloned() {
            if let Some(batch) = b.push(r, now) {
                flushed += batch.requests.len();
            }
        }
        std::hint::black_box(flushed);
    });
    let s = Summary::from_samples(&xs);
    println!(
        "push 10k requests: {:.3} ± {:.3} ms  ({:.0} ns/request)",
        s.mean,
        s.std,
        s.mean * 1e6 / 10_000.0
    );
}

fn bench_service() {
    println!("--- end-to-end service (native engine, 1000 VdP requests) ---");
    for (max_batch, wait_ms) in [(8usize, 1u64), (32, 1), (128, 2)] {
        let coord = Coordinator::spawn(
            ServiceConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
            || Box::new(NativeEngine::default()),
        );
        let mut rng = Rng64::new(7);
        let n = 1000;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n).map(|_| coord.submit(req(&mut rng, 0))).collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv_timeout(Duration::from_secs(120)).is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<4} wait={wait_ms}ms: {ok}/{n} in {wall:.2}s = {:>7.0} req/s | {}",
            n as f64 / wall,
            coord.metrics().summary()
        );
    }
}

/// Threads sweep of the sharded parallel solve: a heterogeneous VdP
/// batch (mixed stiffness, the workload the batcher actually produces)
/// solved end to end per worker count. Results are bitwise-identical
/// across counts; only the wall time changes.
fn bench_threads_sweep() {
    println!("--- sharded parallel solve: threads sweep (heterogeneous VdP, dopri5, tol 1e-5) ---");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("(available cores: {cores})");
    for &batch in &[64usize, 256] {
        let mut rng = Rng64::new(11);
        let mus: Vec<f64> = (0..batch).map(|_| rng.range(0.5, 15.0)).collect();
        let sys = rode::problems::VdP::new(mus);
        let y0 = BatchVec::from_rows(
            &(0..batch)
                .map(|_| vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)])
                .collect::<Vec<_>>(),
        );
        let grid = TimeGrid::linspace_shared(batch, 0.0, 10.0, 20);
        let rows = threads_sweep(&[1, 2, 4, 8], 1, 5, |threads| {
            let opts = SolveOptions::new(Method::Dopri5)
                .with_tols(1e-5, 1e-5)
                .with_max_steps(1_000_000)
                .with_threads(threads);
            let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            std::hint::black_box(sol.ys_flat()[0]);
        });
        let serial = rows[0].1.mean;
        for (threads, s) in &rows {
            println!(
                "batch={batch:<4} threads={threads:<2} {:>8.2} ± {:>5.2} ms   speedup x{:.2}",
                s.mean,
                s.std,
                serial / s.mean
            );
        }
    }
}

fn main() {
    bench_batcher();
    bench_service();
    bench_threads_sweep();
}
