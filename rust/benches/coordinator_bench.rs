//! Bench: the L3 coordinator — batcher throughput and end-to-end service
//! latency across batching configurations.
//!
//! Run with `cargo bench --bench coordinator_bench`.

use rode::bench::{time_repeats, Summary};
use rode::coordinator::{
    Coordinator, DynamicBatcher, NativeEngine, ProblemSpec, ServiceConfig, SolveRequest,
};
use rode::nn::Rng64;
use std::time::{Duration, Instant};

fn req(rng: &mut Rng64, id: u64) -> SolveRequest {
    SolveRequest {
        id,
        problem: ProblemSpec::Vdp { mu: rng.range(0.5, 10.0) },
        y0: vec![rng.normal(), rng.normal()],
        t_eval: (0..20).map(|k| k as f64 * 0.25).collect(),
    }
}

fn bench_batcher() {
    println!("--- DynamicBatcher push throughput ---");
    let mut rng = Rng64::new(1);
    let reqs: Vec<SolveRequest> = (0..10_000).map(|i| req(&mut rng, i)).collect();
    let xs = time_repeats(2, 10, || {
        let mut b = DynamicBatcher::new(64, Duration::from_millis(1));
        let now = Instant::now();
        let mut flushed = 0;
        for r in reqs.iter().cloned() {
            if let Some(batch) = b.push(r, now) {
                flushed += batch.requests.len();
            }
        }
        std::hint::black_box(flushed);
    });
    let s = Summary::from_samples(&xs);
    println!(
        "push 10k requests: {:.3} ± {:.3} ms  ({:.0} ns/request)",
        s.mean,
        s.std,
        s.mean * 1e6 / 10_000.0
    );
}

fn bench_service() {
    println!("--- end-to-end service (native engine, 1000 VdP requests) ---");
    for (max_batch, wait_ms) in [(8usize, 1u64), (32, 1), (128, 2)] {
        let coord = Coordinator::spawn(
            ServiceConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
            || Box::new(NativeEngine::default()),
        );
        let mut rng = Rng64::new(7);
        let n = 1000;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n).map(|_| coord.submit(req(&mut rng, 0))).collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv_timeout(Duration::from_secs(120)).is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<4} wait={wait_ms}ms: {ok}/{n} in {wall:.2}s = {:>7.0} req/s | {}",
            n as f64 / wall,
            coord.metrics().summary()
        );
    }
}

fn main() {
    bench_batcher();
    bench_service();
}
