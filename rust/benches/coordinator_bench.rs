//! Bench: the L3 coordinator — batcher throughput and end-to-end service
//! latency across batching configurations — plus the solver's straggler
//! perf smoke.
//!
//! Run with `cargo bench --bench coordinator_bench`, or pass section
//! names to run a subset (`batcher`, `service`, `threads`, `straggler`,
//! `stiffsweep`, `pdesweep`, `replay`, `adjointsweep`), e.g. `cargo bench
//! --bench coordinator_bench -- straggler`. The straggler section writes
//! machine-readable `BENCH_solver.json` (the stiffsweep, pdesweep,
//! replay and adjointsweep sections append to it) so CI can track the
//! perf trajectory per PR.

use rode::bench::{
    append_bench_json, straggler_workload, threads_sweep, time_repeats, vdp_stiff_span,
    write_bench_json, BenchRecord, Summary,
};
use rode::coordinator::{
    Coordinator, DynamicBatcher, NativeEngine, ProblemSpec, ServiceConfig, SolveRequest,
};
use rode::exec::solve_ivp_parallel_pooled;
use rode::nn::Rng64;
use rode::solver::reference::solve_ivp_parallel_reference;
use rode::solver::{
    backsolve_adjoint_parallel, rk_backward_adaptive, rk_forward_tape_adaptive, solve_ivp_parallel,
    AdjointOptions, MethodId, PoolKind, SolveOptions, TimeGrid,
};
use rode::tensor::BatchVec;
use std::time::{Duration, Instant};

fn req(rng: &mut Rng64, id: u64) -> SolveRequest {
    let mut r = SolveRequest::new(
        ProblemSpec::Vdp { mu: rng.range(0.5, 10.0) },
        vec![rng.normal(), rng.normal()],
        (0..20).map(|k| k as f64 * 0.25).collect(),
    );
    r.id = id;
    r
}

fn bench_batcher() {
    println!("--- DynamicBatcher push throughput ---");
    let mut rng = Rng64::new(1);
    let reqs: Vec<SolveRequest> = (0..10_000).map(|i| req(&mut rng, i)).collect();
    let xs = time_repeats(2, 10, || {
        let mut b = DynamicBatcher::new(64, Duration::from_millis(1));
        let now = Instant::now();
        let mut flushed = 0;
        for r in reqs.iter().cloned() {
            if let Some(batch) = b.push(r, now) {
                flushed += batch.requests.len();
            }
        }
        std::hint::black_box(flushed);
    });
    let s = Summary::from_samples(&xs);
    println!(
        "push 10k requests: {:.3} ± {:.3} ms  ({:.0} ns/request)",
        s.mean,
        s.std,
        s.mean * 1e6 / 10_000.0
    );
}

fn bench_service() {
    println!("--- end-to-end service (native engine, 1000 VdP requests) ---");
    for (max_batch, wait_ms) in [(8usize, 1u64), (32, 1), (128, 2)] {
        let coord = Coordinator::spawn(
            // max_queue 0: unbounded, the historical semantics of this
            // section — shedding is measured by the replay section.
            ServiceConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
                max_queue: 0,
                ..ServiceConfig::default()
            },
            || Box::new(NativeEngine::default()),
        );
        let mut rng = Rng64::new(7);
        let n = 1000;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n).map(|_| coord.submit(req(&mut rng, 0))).collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv_timeout(Duration::from_secs(120)).is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "max_batch={max_batch:<4} wait={wait_ms}ms: {ok}/{n} in {wall:.2}s = {:>7.0} req/s | {}",
            n as f64 / wall,
            coord.metrics().summary()
        );
    }
}

/// Threads sweep of the sharded parallel solve: a heterogeneous VdP
/// batch (mixed stiffness, the workload the batcher actually produces)
/// solved end to end per worker count. Results are bitwise-identical
/// across counts; only the wall time changes.
fn bench_threads_sweep() {
    println!("--- sharded parallel solve: threads sweep (heterogeneous VdP, dopri5, tol 1e-5) ---");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("(available cores: {cores})");
    for &batch in &[64usize, 256] {
        let mut rng = Rng64::new(11);
        let mus: Vec<f64> = (0..batch).map(|_| rng.range(0.5, 15.0)).collect();
        let sys = rode::problems::VdP::new(mus);
        let y0 = BatchVec::from_rows(
            &(0..batch)
                .map(|_| vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)])
                .collect::<Vec<_>>(),
        );
        let grid = TimeGrid::linspace_shared(batch, 0.0, 10.0, 20);
        let rows = threads_sweep(&[1, 2, 4, 8], 1, 5, |threads| {
            let opts = SolveOptions::new(MethodId::DOPRI5)
                .with_tols(1e-5, 1e-5)
                .with_max_steps(1_000_000)
                .with_threads(threads);
            let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            std::hint::black_box(sol.ys_flat()[0]);
        });
        let serial = rows[0].1.mean;
        for (threads, s) in &rows {
            println!(
                "batch={batch:<4} threads={threads:<2} {:>8.2} ± {:>5.2} ms   speedup x{:.2}",
                s.mean,
                s.std,
                serial / s.mean
            );
        }
    }
}

/// The straggler perf smoke: batch 256, one stiff VdP row plus 255 easy
/// rows, `eval_inactive = false`. Measures the frozen pre-active-set
/// loop (the "current main" baseline), the active-set loop, the
/// active-set loop with compaction, and — the pool comparison — the
/// scoped contiguous-shard pool against the persistent work-stealing
/// pool at 4 threads, and writes everything into `BENCH_solver.json`.
/// The scoped pool piles the stiff row plus 63 easy rows onto one
/// worker; the stealing pool isolates it at steal-chunk granularity
/// while the easy chunks migrate to idle workers.
fn bench_straggler() {
    println!("--- straggler batch (1 stiff VdP + 255 easy, dopri5, eval_inactive=false) ---");
    let batch = 256;
    let (sys, y0, grid) = straggler_workload(batch, 60.0, 0.5, 12.0, 20);
    let base = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-6)
        .with_max_steps(1_000_000)
        .skip_inactive();

    let mut records = Vec::new();
    let mut measure = |name: &str, threshold: f64, run: &mut dyn FnMut()| -> f64 {
        let xs = time_repeats(1, 5, run);
        let s = Summary::from_samples(&xs);
        println!("{name:<22} {:>9.2} ± {:>6.2} ms", s.mean, s.std);
        records.push(
            BenchRecord::new(name, &s)
                .field("batch", batch as f64)
                .field("threshold", threshold)
                .field("eval_inactive", 0.0),
        );
        s.mean
    };

    let opts_ref = base.clone();
    let t_ref = measure("masked-reference", 0.0, &mut || {
        let sol = solve_ivp_parallel_reference(&sys, &y0, &grid, &opts_ref);
        assert!(sol.all_success());
        std::hint::black_box(sol.ys_flat()[0]);
    });
    let opts_act = base.clone();
    let t_act = measure("active-set", 0.0, &mut || {
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts_act);
        assert!(sol.all_success());
        std::hint::black_box(sol.ys_flat()[0]);
    });
    let opts_cmp = base.clone().with_compaction(0.5);
    let t_cmp = measure("active-set+compact0.5", 0.5, &mut || {
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts_cmp);
        assert!(sol.all_success());
        std::hint::black_box(sol.ys_flat()[0]);
    });

    for r in records.iter_mut() {
        let speedup = t_ref / r.mean_ms;
        r.fields.push(("speedup_vs_reference".to_string(), speedup));
    }
    println!(
        "speedup vs masked reference: active-set x{:.2}, +compaction x{:.2}",
        t_ref / t_act,
        t_ref / t_cmp
    );

    // Pool comparison at 4 threads, under torchode's exact semantics
    // (eval_inactive = true): finished rows keep receiving overhanging
    // evaluations while materialized, so the scoped shard that owns the
    // stiff row pays for all 64 of its rows for the whole solve, while
    // the stealing pool confines that cost to the stiff row's 8-row
    // chunk and migrates every other chunk to idle workers. Both pooled
    // runs must agree with the serial solve bitwise.
    println!("--- straggler pools (same batch, 4 threads, eval_inactive=true) ---");
    let pool_base =
        SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(1_000_000);
    let serial = solve_ivp_parallel(&sys, &y0, &grid, &pool_base);
    let mut measure_pool = |name: &str, opts: &SolveOptions| -> f64 {
        let mut stats = None;
        let xs = time_repeats(1, 5, || {
            let sol = solve_ivp_parallel_pooled(&sys, &y0, &grid, opts);
            assert!(sol.all_success());
            for (a, b) in sol.ys_flat().iter().zip(serial.ys_flat()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}: pooled result drifted");
            }
            stats = Some(sol.exec_stats);
            std::hint::black_box(sol.ys_flat()[0]);
        });
        let s = Summary::from_samples(&xs);
        let es = stats.unwrap();
        println!(
            "{name:<22} {:>9.2} ± {:>6.2} ms   (pool={} shards={} steals={})",
            s.mean,
            s.std,
            es.pool_kind.name(),
            es.shards,
            es.steal_count
        );
        records.push(
            BenchRecord::new(name, &s)
                .field("batch", batch as f64)
                .field("threads", 4.0)
                .field("eval_inactive", 1.0)
                .field("shards", es.shards as f64)
                .field("steal_count", es.steal_count as f64),
        );
        s.mean
    };
    let opts_scoped = pool_base.clone().with_threads(4).with_pool(PoolKind::Scoped);
    let t_scoped = measure_pool("pool-scoped-4t", &opts_scoped);
    let opts_steal = pool_base
        .clone()
        .with_threads(4)
        .with_pool(PoolKind::Persistent)
        .with_steal_chunk(8);
    let t_steal = measure_pool("pool-stealing-4t", &opts_steal);
    println!("persistent+stealing vs scoped shards: x{:.2}", t_scoped / t_steal);
    let n = records.len();
    records[n - 1].fields.push(("speedup_vs_scoped".to_string(), t_scoped / t_steal));
    records[n - 2].fields.push(("speedup_vs_scoped".to_string(), 1.0));

    match write_bench_json("BENCH_solver.json", &records) {
        Ok(()) => println!("wrote BENCH_solver.json ({} records)", records.len()),
        Err(e) => eprintln!("failed to write BENCH_solver.json: {e}"),
    }
}

/// The stiffness sweep: a VdP μ sweep comparing the implicit TR-BDF2
/// method against explicit Dopri5 — wall time, steps-to-solve and the
/// per-instance dynamics-evaluation accounting (including the implicit
/// method's `n_jac_evals`/`n_lu_factor`). At μ = 10 the problem is
/// non-stiff and the explicit method should win; by μ = 100 the
/// stability cap on the explicit step has flipped the ranking; at
/// μ = 1000 the explicit solver exhausts its step budget (recorded as
/// `explicit_success = 0`) while the implicit method strolls through —
/// the wall the implicit subsystem removes. Appends
/// `stiffsweep-mu{μ}` records to `BENCH_solver.json`
/// (`speedup_vs_explicit` carries floors in `BENCH_baseline.json` for
/// the μ where the explicit method finishes).
///
/// A second leg pits Kvaerno 4(3) against TR-BDF2 at tight tolerances
/// (atol = rtol = 1e-8), where the order-4 method's larger stable-accurate
/// step should need *fewer accepted steps* for the same trajectory.
/// Appends `stiffsweep-kvaerno43-mu{μ}` records whose `steps_vs_trbdf2`
/// ratio (TR-BDF2 accepted steps / Kvaerno accepted steps, > 1 means
/// Kvaerno wins) carries an advisory floor in `BENCH_baseline.json`.
fn bench_stiffsweep() {
    println!("--- stiffsweep (batch 16 VdP, trbdf2 vs dopri5, tol 1e-6/1e-4) ---");
    let batch = 16;
    let mut records = Vec::new();
    for &mu in &[10.0f64, 100.0, 1000.0] {
        let sys = rode::problems::VdP::uniform(batch, mu);
        let y0 = BatchVec::broadcast(&[2.0, 0.0], batch);
        let t1 = vdp_stiff_span(mu);
        let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 8);

        let mut run = |method: MethodId,
                       tols: (f64, f64),
                       max_steps: usize,
                       warmup: usize,
                       reps: usize| {
            let opts =
                SolveOptions::new(method).with_tols(tols.0, tols.1).with_max_steps(max_steps);
            let mut steps = 0u64;
            let mut accepted = 0u64;
            let mut fevals = 0u64;
            let mut jacs = 0u64;
            let mut success = true;
            let xs = time_repeats(warmup, reps, || {
                let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
                success = sol.all_success();
                steps = sol.max_steps();
                accepted = sol.stats[0].n_accepted;
                fevals = sol.stats[0].n_f_evals;
                jacs = sol.stats[0].n_jac_evals;
                std::hint::black_box(sol.ys_flat()[0]);
            });
            (Summary::from_samples(&xs), steps, accepted, fevals, jacs, success)
        };

        let (s_imp, steps_imp, _, fe_imp, jac_imp, ok_imp) =
            run(MethodId::TRBDF2, (1e-6, 1e-4), 500_000, 1, 3);
        assert!(ok_imp, "mu={mu}: implicit must solve the sweep");
        // The explicit leg gets a bounded budget, probed once: at
        // μ = 1000 it cannot finish inside it (stability caps dt ~ 1e-3
        // over a span of 400), and re-timing a known budget-exhausting
        // failure would just burn CI time — only a successful leg is
        // re-run for a fair timing.
        let probe = run(MethodId::DOPRI5, (1e-6, 1e-4), 200_000, 0, 1);
        let (s_exp, steps_exp, _, fe_exp, _, ok_exp) =
            if probe.5 { run(MethodId::DOPRI5, (1e-6, 1e-4), 200_000, 1, 3) } else { probe };
        let speedup = s_exp.mean / s_imp.mean;
        // Only a successful explicit leg yields a meaningful ratio; a
        // failed probe's wall time is just its budget burning down.
        let speedup_txt =
            if ok_exp { format!("x{speedup:.2}") } else { "n/a (explicit failed)".to_string() };
        println!(
            "mu={mu:<6} trbdf2 {:>9.2} ms ({steps_imp:>6} steps, {fe_imp:>8} f, \
             {jac_imp:>5} jac) | dopri5 {:>9.2} ms ({steps_exp:>6} steps, {fe_exp:>8} f, \
             success={ok_exp}) | {speedup_txt}",
            s_imp.mean,
            s_exp.mean
        );
        let mut rec = BenchRecord::new(&format!("stiffsweep-mu{mu}"), &s_imp)
            .field("mu", mu)
            .field("batch", batch as f64)
            .field("t1", t1)
            .field("implicit_steps", steps_imp as f64)
            .field("implicit_f_evals", fe_imp as f64)
            .field("implicit_jac_evals", jac_imp as f64)
            .field("explicit_ms", s_exp.mean)
            .field("explicit_steps", steps_exp as f64)
            .field("explicit_success", if ok_exp { 1.0 } else { 0.0 });
        if ok_exp {
            rec = rec.field("speedup_vs_explicit", speedup);
        }
        records.push(rec);

        // The ESDIRK-vs-ESDIRK leg: tight tolerances, where method order
        // (not stability) sets the step count. Step counts are exactly
        // reproducible, so warmup 0 / one rep suffices — the wall time is
        // recorded for context only.
        let (s_tr, _, acc_tr, _, _, ok_tr) =
            run(MethodId::TRBDF2, (1e-8, 1e-8), 2_000_000, 0, 1);
        let (s_kv, _, acc_kv, _, jac_kv, ok_kv) =
            run(MethodId::KVAERNO43, (1e-8, 1e-8), 2_000_000, 0, 1);
        assert!(ok_tr && ok_kv, "mu={mu}: tight-tolerance legs must solve");
        assert!(
            acc_kv < acc_tr,
            "mu={mu}: kvaerno43 accepted {acc_kv} steps, trbdf2 {acc_tr} — the \
             order-4 pair should need fewer at tol 1e-8"
        );
        let ratio = acc_tr as f64 / acc_kv as f64;
        println!(
            "mu={mu:<6} tol 1e-8: kvaerno43 {:>9.2} ms ({acc_kv:>6} acc) | trbdf2 \
             {:>9.2} ms ({acc_tr:>6} acc) | steps x{ratio:.2}",
            s_kv.mean, s_tr.mean
        );
        records.push(
            BenchRecord::new(&format!("stiffsweep-kvaerno43-mu{mu}"), &s_kv)
                .field("mu", mu)
                .field("batch", batch as f64)
                .field("accepted_steps", acc_kv as f64)
                .field("jac_evals", jac_kv as f64)
                .field("trbdf2_ms", s_tr.mean)
                .field("trbdf2_accepted_steps", acc_tr as f64)
                .field("steps_vs_trbdf2", ratio),
        );
    }
    match append_bench_json("BENCH_solver.json", &records) {
        Ok(()) => println!("appended {} stiffsweep records to BENCH_solver.json", records.len()),
        Err(e) => eprintln!("failed to write BENCH_solver.json: {e}"),
    }
}

/// The PDE dim sweep: Fisher–KPP reaction–diffusion (method of lines,
/// tridiagonal Jacobian) under TR-BDF2, comparing the banded Newton path
/// against the forced-dense path (`SolveOptions::with_jac_structure`) at
/// dim {64, 256, 1024}. Both paths must produce **bitwise-identical**
/// trajectories — the banded factorization is a cost win, not a
/// different computation — so the wall-time ratio
/// (`speedup_banded_vs_dense`, O(dim·bw²) vs O(dim³) factor work) is the
/// whole story. Appends `pdesweep-d{dim}` records to
/// `BENCH_solver.json`; the dim-1024 ratio carries an enforced floor in
/// `BENCH_baseline.json` (advisory at 64/256).
///
/// A final dim-4096 leg runs the banded path alone: the dense Newton
/// scratch there would need ~2 × dim² × 8 B ≈ 270 MB *per row* and
/// ~2·10¹⁰ flops per factorization — the dense path is infeasible, which
/// is exactly the capability the banded path adds. Completing with
/// `Status::Success` is the acceptance bar; the record is untracked.
fn bench_pdesweep() {
    println!("--- pdesweep (reaction-diffusion, trbdf2, banded vs forced-dense Newton) ---");
    let batch = 4;
    let mut records = Vec::new();
    for &dim in &[64usize, 256, 1024] {
        let sys = rode::problems::ReactionDiffusion::sweep(batch, dim);
        let y0 = BatchVec::from_rows(&sys.front_y0(batch));
        let grid = TimeGrid::linspace_shared(batch, 0.0, 0.1, 3);
        let base =
            SolveOptions::new(MethodId::TRBDF2).with_tols(1e-6, 1e-4).with_max_steps(500_000);
        // The dense leg at dim 1024 factors ~GB-scale flop counts per
        // repeat; one timed rep keeps the section inside a CI budget.
        let (warmup, reps) = if dim >= 1024 { (0, 1) } else { (1, 3) };

        let mut run = |opts: &SolveOptions| {
            let mut steps = 0u64;
            let mut lu = 0u64;
            let mut jacs = 0u64;
            let mut ys: Vec<u64> = Vec::new();
            let xs = time_repeats(warmup, reps, || {
                let sol = solve_ivp_parallel(&sys, &y0, &grid, opts);
                assert!(sol.all_success(), "pdesweep d{dim}: {:?}", &sol.status[..2]);
                steps = sol.max_steps();
                lu = sol.stats.iter().map(|s| s.n_lu_factor).sum();
                jacs = sol.stats.iter().map(|s| s.n_jac_evals).sum();
                ys = sol.ys_flat().iter().map(|v| v.to_bits()).collect();
                std::hint::black_box(sol.ys_flat()[0]);
            });
            (Summary::from_samples(&xs), steps, lu, jacs, ys)
        };

        let (s_band, steps, lu_band, jacs, ys_band) = run(&base);
        let (s_dense, _, lu_dense, _, ys_dense) =
            run(&base.clone().with_jac_structure(rode::problems::JacStructure::Dense));
        assert_eq!(
            ys_band, ys_dense,
            "d{dim}: banded and forced-dense trajectories must be bitwise identical"
        );
        let speedup = s_dense.mean / s_band.mean;
        println!(
            "dim={dim:<5} banded {:>9.2} ms ({steps:>5} steps, {lu_band:>6} lu) | dense \
             {:>9.2} ms ({lu_dense:>6} lu) | banded x{speedup:.2}",
            s_band.mean, s_dense.mean
        );
        records.push(
            BenchRecord::new(&format!("pdesweep-d{dim}"), &s_band)
                .field("dim", dim as f64)
                .field("batch", batch as f64)
                .field("steps", steps as f64)
                .field("jac_evals", jacs as f64)
                .field("n_lu_factor", lu_band as f64)
                .field("dense_ms", s_dense.mean)
                .field("dense_n_lu_factor", lu_dense as f64)
                .field("speedup_banded_vs_dense", speedup),
        );
    }

    {
        let dim = 4096usize;
        let batch = 2;
        let sys = rode::problems::ReactionDiffusion::sweep(batch, dim);
        let y0 = BatchVec::from_rows(&sys.front_y0(batch));
        let grid = TimeGrid::linspace_shared(batch, 0.0, 0.05, 3);
        let opts =
            SolveOptions::new(MethodId::TRBDF2).with_tols(1e-6, 1e-4).with_max_steps(500_000);
        let mut steps = 0u64;
        let mut lu = 0u64;
        let xs = time_repeats(0, 1, || {
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(sol.all_success(), "pdesweep d4096 banded: {:?}", &sol.status);
            steps = sol.max_steps();
            lu = sol.stats.iter().map(|s| s.n_lu_factor).sum();
            std::hint::black_box(sol.ys_flat()[0]);
        });
        let s = Summary::from_samples(&xs);
        println!(
            "dim=4096 banded {:>9.2} ms ({steps} steps, {lu} lu) — dense infeasible, \
             banded-only leg",
            s.mean
        );
        records.push(
            BenchRecord::new("pdesweep-d4096-banded", &s)
                .field("dim", dim as f64)
                .field("batch", batch as f64)
                .field("steps", steps as f64)
                .field("n_lu_factor", lu as f64),
        );
    }

    match append_bench_json("BENCH_solver.json", &records) {
        Ok(()) => println!("appended {} pdesweep records to BENCH_solver.json", records.len()),
        Err(e) => eprintln!("failed to write BENCH_solver.json: {e}"),
    }
}

/// The serving-shaped mixed trace the replay legs share: mostly easy VdP
/// (several grid shapes, so a fleet has more than one bucket to spread),
/// a stiff tail that dies on the explicit default, and a sliver of
/// malformed (NaN-state) requests the service must absorb.
fn replay_trace(n: usize) -> (Vec<SolveRequest>, u64, u64, u64) {
    let mut rng = Rng64::new(23);
    let mut trace = Vec::with_capacity(n);
    let (mut n_easy, mut n_stiff, mut n_bad) = (0u64, 0u64, 0u64);
    for _ in 0..n {
        let roll = rng.below(100);
        let r = if roll < 85 {
            n_easy += 1;
            let n_eval = [10usize, 20, 40, 80][rng.below(4)];
            SolveRequest::new(
                ProblemSpec::Vdp { mu: rng.range(0.5, 10.0) },
                vec![rng.normal(), rng.normal()],
                (0..n_eval).map(|k| k as f64 * 0.25).collect(),
            )
        } else if roll < 95 {
            // Dies of DtUnderflow on dopri5 under the engine options
            // below, solves on trbdf2 (pinned in tests/stiff_regression.rs)
            // — exercises the escalation path end to end.
            n_stiff += 1;
            SolveRequest::new(
                ProblemSpec::Vdp { mu: 1000.0 },
                vec![2.0, 0.0],
                (0..5).map(|k| k as f64 * 100.0).collect(),
            )
        } else {
            // Malformed: a NaN state is NonFinite on every method, so
            // these burn a retry and still fail — hostile traffic the
            // service must absorb without stalling.
            n_bad += 1;
            SolveRequest::new(
                ProblemSpec::Vdp { mu: 2.0 },
                vec![f64::NAN, 0.0],
                (0..20).map(|k| k as f64 * 0.25).collect(),
            )
        };
        trace.push(r);
    }
    (trace, n_easy, n_stiff, n_bad)
}

/// What one replay leg measured (throughput + degraded-mode counters).
struct ReplayLeg {
    wall_ms: f64,
    admitted: u64,
    ok: u64,
    escalated_ok: u64,
    shed: u64,
    retried: u64,
    expired: u64,
    req_per_s: f64,
    success_rate: f64,
    classified: u64,
    cls_hits: u64,
    cls_misses: u64,
}

/// Fire the trace at a fresh coordinator with the given fleet size and
/// classifier setting, as fast as possible, and collect the counters.
fn run_replay(trace: Vec<SolveRequest>, workers: usize, classifier_on: bool) -> ReplayLeg {
    use std::sync::atomic::Ordering;
    // Pin the explicit method's minimum step above its stability ceiling
    // at μ = 1000 so the stiff tail genuinely underflows (same options as
    // the stiff-regression pin).
    let mut opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-6, 1e-4)
        .with_dt0(0.01)
        .with_max_steps(500_000);
    opts.min_dt_rel = 1e-5;
    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(1),
            max_queue: 512,
            workers,
            classifier: if classifier_on {
                rode::coordinator::ClassifierPolicy::enabled()
            } else {
                rode::coordinator::ClassifierPolicy::default()
            },
            ..ServiceConfig::default()
        },
        move || Box::new(NativeEngine::new(opts.clone())),
    );

    let n = trace.len() as u64;
    let t0 = Instant::now();
    let rxs: Vec<_> = trace.into_iter().map(|r| coord.submit(r)).collect();
    let mut ok = 0u64;
    let mut escalated_ok = 0u64;
    for rx in rxs {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(300)) {
            if resp.is_success() {
                ok += 1;
                if resp.escalated_from.is_some() {
                    escalated_ok += 1;
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    let shed = m.requests_shed.load(Ordering::Relaxed);
    let admitted = n - shed;
    println!("{}", m.summary());
    ReplayLeg {
        wall_ms: wall * 1e3,
        admitted,
        ok,
        escalated_ok,
        shed,
        retried: m.requests_retried.load(Ordering::Relaxed),
        expired: m.requests_deadline_expired.load(Ordering::Relaxed),
        req_per_s: admitted as f64 / wall,
        success_rate: ok as f64 / admitted.max(1) as f64,
        classified: m.classified_stiff.load(Ordering::Relaxed),
        cls_hits: m.classifier_hits.load(Ordering::Relaxed),
        cls_misses: m.classifier_misses.load(Ordering::Relaxed),
    }
}

fn replay_record(name: &str, n: usize, leg: &ReplayLeg) -> BenchRecord {
    let s = Summary::from_samples(&[leg.wall_ms]);
    BenchRecord::new(name, &s)
        .field("n_requests", n as f64)
        .field("admitted", leg.admitted as f64)
        .field("succeeded", leg.ok as f64)
        .field("escalated_ok", leg.escalated_ok as f64)
        .field("shed", leg.shed as f64)
        .field("retried", leg.retried as f64)
        .field("expired", leg.expired as f64)
        .field("req_per_s", leg.req_per_s)
        .field("replay_success_rate", leg.success_rate)
}

/// Trace replay: the mixed trace fired at a bounded queue, in three legs.
///
/// - `serve-replay` — one worker, classifier off: the historical record
///   (`replay_success_rate` carries a floor in `BENCH_baseline.json` —
///   malformed traffic fails by design, so the floor sits below the
///   easy+stiff fraction).
/// - `serve-replay-w4` — four workers, classifier off: the fleet
///   throughput leg; `replay_throughput_w4_vs_w1` (advisory floor) is
///   the four-worker speedup over the one-worker leg.
/// - `serve-replay-classified` — four workers, classifier on: the stiff
///   tail is routed to trbdf2 *before* the first solve, so `retried`
///   drops to roughly the malformed sliver; `classifier_hit_rate`
///   (advisory floor) is hits over classified-stiff.
fn bench_replay() {
    println!("--- serve replay (mixed easy/stiff/malformed trace, bounded queue) ---");
    let n = 2000usize;
    let (trace, n_easy, n_stiff, n_bad) = replay_trace(n);
    println!("trace: {n_easy} easy / {n_stiff} stiff / {n_bad} malformed");

    let mut legs = Vec::new();
    for (tag, workers, classifier_on) in
        [("w1", 1usize, false), ("w4", 4, false), ("w4+classifier", 4, true)]
    {
        let leg = run_replay(trace.clone(), workers, classifier_on);
        println!(
            "{tag:<14} {}/{} admitted ok ({} via escalation) in {:.2}s = {:>7.0} req/s | \
             shed={} retried={} classified={}",
            leg.ok,
            leg.admitted,
            leg.escalated_ok,
            leg.wall_ms / 1e3,
            leg.req_per_s,
            leg.shed,
            leg.retried,
            leg.classified
        );
        legs.push(leg);
    }
    let (w1, w4, cls) = (&legs[0], &legs[1], &legs[2]);
    let throughput_ratio = w4.req_per_s / w1.req_per_s.max(1e-9);
    let hit_rate = cls.cls_hits as f64 / cls.classified.max(1) as f64;
    println!(
        "fleet throughput w4/w1: x{throughput_ratio:.2} | classifier: {}/{} hits \
         ({} misses), retried {} -> {} vs classifier-off",
        cls.cls_hits, cls.classified, cls.cls_misses, w4.retried, cls.retried
    );

    let records = [
        replay_record("serve-replay", n, w1),
        replay_record("serve-replay-w4", n, w4)
            .field("workers", 4.0)
            .field("replay_throughput_w4_vs_w1", throughput_ratio),
        replay_record("serve-replay-classified", n, cls)
            .field("workers", 4.0)
            .field("classified_stiff", cls.classified as f64)
            .field("classifier_misses", cls.cls_misses as f64)
            .field("retried_without_classifier", w4.retried as f64)
            .field("classifier_hit_rate", hit_rate),
    ];
    match append_bench_json("BENCH_solver.json", &records) {
        Ok(()) => println!("appended {} serve-replay records to BENCH_solver.json", records.len()),
        Err(e) => eprintln!("failed to write BENCH_solver.json: {e}"),
    }
}

/// The adjoint sweep: backsolve vs adaptive-tape wall time and tape
/// memory on the two adjoint-shaped workloads — a heterogeneous VdP
/// batch (tiny state, one parameter: the two adjoints cost about the
/// same) and the CNF model (the parameter block dominates the augmented
/// backsolve state `b·(2f+p)`, while the tape only stores `f`-sized
/// stages: discretize-then-optimize wins wall time, the backsolve wins
/// memory). Appends `adjointsweep-vdp` / `adjointsweep-cnf` records to
/// `BENCH_solver.json`; `speedup_tape_vs_backsolve` carries advisory
/// floors in `BENCH_baseline.json`, and `tape_bytes` records the memory
/// the backsolve avoids.
fn bench_adjointsweep() {
    println!("--- adjointsweep (backsolve vs adaptive tape, VdP + CNF) ---");
    let mut records = Vec::new();

    let mut leg = |name: &str,
                   sys: &dyn rode::problems::OdeSystem,
                   y0: &BatchVec,
                   dl: &BatchVec,
                   t1: f64| {
        let b = y0.batch();
        let grid = TimeGrid::linspace_shared(b, 0.0, t1, 2);
        let fw =
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(200_000);

        // Adaptive tape: traced forward + replay + exact discrete backward.
        let mut tape_bytes = 0usize;
        let mut tape_steps = 0usize;
        let xs_tape = time_repeats(1, 3, || {
            let (sol, tape) = rk_forward_tape_adaptive(sys, y0, 0.0, t1, &fw);
            assert!(sol.all_success());
            let (dy0, dp) = rk_backward_adaptive(sys, &tape, dl);
            tape_bytes = tape.tape_bytes();
            tape_steps = tape.total_steps();
            std::hint::black_box((dy0.row(0)[0], dp.first().copied()));
        });
        let s_tape = Summary::from_samples(&xs_tape);

        // Backsolve: plain forward + O(checkpoints)-memory continuous adjoint.
        let adj = AdjointOptions::new(fw.clone()).with_checkpoints(4);
        let t0s = vec![0.0; b];
        let t1s = vec![t1; b];
        let mut bw_steps = 0u64;
        let xs_back = time_repeats(1, 3, || {
            let sol = solve_ivp_parallel(sys, y0, &grid, &fw);
            assert!(sol.all_success());
            let mut y1 = BatchVec::zeros(b, y0.dim());
            for i in 0..b {
                y1.row_mut(i).copy_from_slice(sol.y_final(i));
            }
            let res = backsolve_adjoint_parallel(sys, y0, &y1, dl, &t0s, &t1s, &adj);
            bw_steps = res.stats.iter().map(|s| s.n_steps).sum();
            std::hint::black_box(res.dl_dy0.row(0)[0]);
        });
        let s_back = Summary::from_samples(&xs_back);
        let speedup = s_back.mean / s_tape.mean;
        println!(
            "{name:<6} tape {:>9.2} ms ({tape_steps:>6} steps, {tape_bytes:>9} B) | backsolve \
             {:>9.2} ms ({bw_steps:>6} bw steps, 0 B) | tape x{speedup:.2}",
            s_tape.mean, s_back.mean
        );
        records.push(
            BenchRecord::new(&format!("adjointsweep-{name}"), &s_tape)
                .field("batch", b as f64)
                .field("dim", y0.dim() as f64)
                .field("tape_bytes", tape_bytes as f64)
                .field("tape_total_steps", tape_steps as f64)
                .field("backsolve_ms", s_back.mean)
                .field("backsolve_steps", bw_steps as f64)
                .field("speedup_tape_vs_backsolve", speedup),
        );
    };

    {
        let b = 16;
        let mut rng = Rng64::new(17);
        let sys = rode::problems::VdP::new((0..b).map(|_| rng.range(0.5, 2.5)).collect());
        let y0 = BatchVec::broadcast(&[1.5, 0.0], b);
        let dl = BatchVec::broadcast(&[1.0, 0.0], b);
        leg("vdp", &sys, &y0, &dl, 2.0);
    }
    {
        let b = 16;
        let d = 2;
        let mut rng = Rng64::new(3);
        let model = rode::problems::CnfDynamics::new(d, &[32, 32], &mut rng);
        let f = d + 1;
        let mut y0 = BatchVec::zeros(b, f);
        let mut dl = BatchVec::zeros(b, f);
        for i in 0..b {
            let c = if rng.uniform() < 0.5 { -1.5 } else { 1.5 };
            y0.row_mut(i)[0] = c + 0.4 * rng.normal();
            y0.row_mut(i)[1] = 0.4 * rng.normal();
            let row = dl.row_mut(i);
            for k in 0..d {
                row[k] = 0.5 / b as f64;
            }
            row[d] = 1.0 / b as f64;
        }
        leg("cnf", &model, &y0, &dl, 1.0);
    }

    match append_bench_json("BENCH_solver.json", &records) {
        Ok(()) => {
            println!("appended {} adjointsweep records to BENCH_solver.json", records.len())
        }
        Err(e) => eprintln!("failed to write BENCH_solver.json: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a == name || a == "all");
    if want("batcher") {
        bench_batcher();
    }
    if want("service") {
        bench_service();
    }
    if want("threads") {
        bench_threads_sweep();
    }
    if want("straggler") {
        bench_straggler();
    }
    if want("stiffsweep") {
        bench_stiffsweep();
    }
    if want("pdesweep") {
        bench_pdesweep();
    }
    if want("replay") {
        bench_replay();
    }
    if want("adjointsweep") {
        bench_adjointsweep();
    }
}
