#!/usr/bin/env python3
"""Gate benchmark regressions against the committed baseline.

Usage:
    compare_bench.py BASELINE.json CURRENT.json [--max-regression PCT]

Both files are `write_bench_json` arrays (see rust/src/bench.rs). The
baseline is a *floor specification*, not a measurement archive: only
dimensionless ratio fields (the `speedup_*` keys below) are compared,
because absolute `mean_ms` values are machine-dependent and would make
the gate meaningless across runners. For every baseline record that
carries a tracked field, the matching current record (by `name`) must

  - exist (a silently renamed or dropped benchmark fails the gate), and
  - keep `current >= baseline * (1 - max_regression/100)` for each
    tracked field present in the baseline record.

A baseline record may carry `"advisory": true`: its floor is still
checked and reported (loudly, as ADVISORY-MISS), but a miss does not
fail the gate. This is the calibration state for floors that have not
yet been backed by a measured CI run — promote them to enforced (drop
the flag, set the floor from observed numbers) once a few runs exist.
A missing record fails the gate even when advisory: silently dropping
a benchmark is never OK.

Exit status 0 = all enforced floors held, 1 = regression or missing
record, 2 = usage/parse error.
"""

import argparse
import json
import sys

TRACKED = (
    "speedup_vs_reference",
    "speedup_vs_scoped",
    "speedup_vs_scalar",
    "speedup_vs_explicit",
    "steps_vs_trbdf2",
    "replay_success_rate",
    "speedup_banded_vs_dense",
    "replay_throughput_w4_vs_w1",
    "classifier_hit_rate",
    "speedup_tape_vs_backsolve",
)


def load(path):
    try:
        with open(path) as f:
            records = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(records, list):
        print(f"error: {path}: expected a JSON array of records", file=sys.stderr)
        sys.exit(2)
    return {r["name"]: r for r in records if isinstance(r, dict) and "name" in r}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=25.0,
        metavar="PCT",
        help="allowed drop below the baseline floor, in percent (default 25)",
    )
    args = ap.parse_args()

    baseline = load(args.baseline)
    current = load(args.current)
    slack = 1.0 - args.max_regression / 100.0

    failures = []
    advisories = []
    checked = 0
    for name, base in sorted(baseline.items()):
        tracked = [k for k in TRACKED if isinstance(base.get(k), (int, float))]
        if not tracked:
            continue
        advisory = bool(base.get("advisory"))
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current results (renamed or dropped?)")
            continue
        for key in tracked:
            floor = base[key] * slack
            got = cur.get(key)
            if not isinstance(got, (int, float)):
                # A conditionally-emitted ratio (e.g. speedup_vs_explicit
                # when the explicit leg failed) is only fatal for
                # enforced floors.
                (advisories if advisory else failures).append(
                    f"{name}: current record has no numeric {key}"
                )
                continue
            checked += 1
            if got >= floor:
                status = "ok"
            elif advisory:
                status = "ADVISORY-MISS"
            else:
                status = "REGRESSED"
            print(
                f"{name:<28} {key:<22} baseline {base[key]:6.2f}  "
                f"floor {floor:6.2f}  current {got:6.2f}  {status}"
            )
            if got < floor:
                msg = (
                    f"{name}: {key} {got:.3f} is below floor {floor:.3f} "
                    f"(baseline {base[key]:.3f} - {args.max_regression:.0f}%)"
                )
                (advisories if advisory else failures).append(msg)

    if advisories:
        print(f"\nadvisory floors missed ({len(advisories)}) — calibrate the baseline:")
        for a in advisories:
            print(f"  - {a}")
    if failures:
        print(f"\nperf gate FAILED ({len(failures)} problem(s)):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(f"\nperf gate passed: {checked} tracked ratio(s) checked, all enforced floors held")


if __name__ == "__main__":
    main()
